"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels.cache_update import (cache_update, cache_update_pallas,
                                        cache_update_ref)
from repro.kernels.fma32 import fma32, fma32_ref
from repro.kernels.stream import stream_triad, stream_triad_ref
from repro.kernels.gemm import gemm, gemm_ref
from repro.kernels.jacobi2d import jacobi2d, jacobi2d_ref
from repro.kernels.gridder import (degridder, degridder_ref, gridder,
                                   gridder_ref)
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)


def rng(i):
    return jax.random.PRNGKey(i)


# -- fma32 ---------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128), (512, 256), (1024, 384)])
@pytest.mark.parametrize("iters", [1, 16, 64])
def test_fma32(shape, iters):
    x = jax.random.normal(rng(0), shape, jnp.float32)
    assert_allclose(fma32(x, iters=iters, interpret=True),
                    fma32_ref(x, iters=iters), rtol=1e-6)


# -- stream --------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(256, 128), (2048, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_triad(shape, dtype):
    a = jax.random.normal(rng(1), shape).astype(dtype)
    b = jax.random.normal(rng(2), shape).astype(dtype)
    out = stream_triad(a, b, scalar=2.5, interpret=True)
    ref = stream_triad_ref(a, b, scalar=2.5)
    assert out.dtype == ref.dtype
    assert_allclose(out.astype(np.float32), ref.astype(np.float32),
                    rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                    atol=1e-6)


# -- gemm ----------------------------------------------------------------------

@pytest.mark.parametrize("mnk", [(256, 256, 256), (512, 256, 384),
                                 (128, 512, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm(mnk, dtype):
    m, n, k = mnk
    a = (jax.random.normal(rng(3), (m, k)) / math.sqrt(k)).astype(dtype)
    b = jax.random.normal(rng(4), (k, n)).astype(dtype)
    out = gemm(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
    ref = gemm_ref(a, b)
    assert out.dtype == jnp.float32
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    assert_allclose(out, ref, rtol=tol, atol=tol)


def test_gemm_block_shape_invariance():
    a = jax.random.normal(rng(5), (512, 512), jnp.float32)
    b = jax.random.normal(rng(6), (512, 512), jnp.float32)
    o1 = gemm(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
    o2 = gemm(a, b, block_m=256, block_n=256, block_k=512, interpret=True)
    assert_allclose(o1, o2, rtol=1e-5, atol=1e-4)


# -- jacobi2d ---------------------------------------------------------------------

@pytest.mark.parametrize("shape,bh", [((256, 128), 64), ((512, 256), 128),
                                      ((128, 384), 128)])
def test_jacobi2d(shape, bh):
    x = jax.random.normal(rng(7), shape, jnp.float32)
    assert_allclose(jacobi2d(x, block_h=bh, interpret=True),
                    jacobi2d_ref(x), rtol=1e-6, atol=1e-6)


def test_jacobi2d_boundary_rows_kept():
    x = jax.random.normal(rng(8), (256, 128), jnp.float32)
    out = jacobi2d(x, block_h=64, interpret=True)
    assert_allclose(out[0], x[0])
    assert_allclose(out[-1], x[-1])
    assert_allclose(out[:, 0], x[:, 0])


# -- gridder / degridder ------------------------------------------------------------

@pytest.mark.parametrize("p,s,v,bv", [(128, 2, 128, 128), (256, 3, 256, 128),
                                      (128, 1, 512, 256)])
def test_gridder(p, s, v, bv):
    lm = jax.random.uniform(rng(9), (p, 2), minval=-0.5, maxval=0.5)
    uv = jax.random.uniform(rng(10), (s, v, 2), minval=-2.0, maxval=2.0)
    vis = jax.random.normal(rng(11), (s, v, 2), jnp.float32)
    assert_allclose(gridder(lm, uv, vis, block_v=bv, interpret=True),
                    gridder_ref(lm, uv, vis), rtol=1e-4, atol=2e-3)


@pytest.mark.parametrize("p,s,v", [(128, 2, 128), (256, 2, 256)])
def test_degridder(p, s, v):
    lm = jax.random.uniform(rng(12), (p, 2), minval=-0.5, maxval=0.5)
    uv = jax.random.uniform(rng(13), (s, v, 2), minval=-2.0, maxval=2.0)
    sub = jax.random.normal(rng(14), (s, p, 2), jnp.float32)
    assert_allclose(degridder(lm, uv, sub, interpret=True),
                    degridder_ref(lm, uv, sub), rtol=1e-4, atol=2e-3)


def test_gridder_degridder_adjoint():
    """<G(vis), sub> == <vis, G^T(sub)> — the pair is a true adjoint."""
    p, s, v = 128, 2, 128
    lm = jax.random.uniform(rng(15), (p, 2), minval=-0.5, maxval=0.5)
    uv = jax.random.uniform(rng(16), (s, v, 2), minval=-1.0, maxval=1.0)
    vis = jax.random.normal(rng(17), (s, v, 2), jnp.float32)
    sub = jax.random.normal(rng(18), (s, p, 2), jnp.float32)
    g = gridder(lm, uv, vis, interpret=True)
    gt = degridder(lm, uv, sub, interpret=True)
    # complex inner products: <a,b> = sum(re*re + im*im) under adjointness
    lhs = float(jnp.sum(g * sub))
    rhs = float(jnp.sum(vis * gt))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-3) < 1e-3


# -- cache_update (per-row KV scatter) ---------------------------------------------

@pytest.mark.parametrize("b,c,f", [(1, 8, 16), (4, 32, 128), (5, 7, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cache_update_exact(b, c, f, dtype):
    """Pallas scatter must match the vmap'd dynamic-update-slice oracle
    to EXACT equality (it moves bytes, it computes nothing)."""
    cache = jax.random.normal(rng(31), (b, c, f)).astype(dtype)
    new = jax.random.normal(rng(32), (b, 1, f)).astype(dtype)
    slots = jax.random.randint(rng(33), (b,), 0, c).astype(jnp.int32)
    out = cache_update_pallas(cache, new, slots, interpret=True)
    ref = cache_update_ref(cache, new, slots)
    assert out.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cache_update_edge_slots_and_duplicates():
    b, c, f = 4, 16, 32
    cache = jax.random.normal(rng(34), (b, c, f), jnp.float32)
    new = jax.random.normal(rng(35), (b, 1, f), jnp.float32)
    # first slot, last slot, and two rows landing on the same slot index
    # (different rows -> no conflict)
    slots = jnp.array([0, c - 1, 5, 5], jnp.int32)
    out = cache_update_pallas(cache, new, slots, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(cache_update_ref(cache, new, slots)))


def test_cache_update_trailing_dims_and_jit():
    """ops.cache_update flattens (B,C,KVH,hd)-shaped caches and runs
    under jit; the lax fallback and interpreted Pallas path agree."""
    b, c, kvh, hd = 3, 12, 2, 8
    cache = jax.random.normal(rng(36), (b, c, kvh, hd), jnp.float32)
    new = jax.random.normal(rng(37), (b, 1, kvh, hd), jnp.float32)
    slots = jnp.array([0, 11, 4], jnp.int32)
    lax_out = jax.jit(lambda *a: cache_update(*a, impl="lax"))(
        cache, new, slots)
    pl_out = jax.jit(lambda *a: cache_update(*a, impl="pallas_interpret"))(
        cache, new, slots)
    np.testing.assert_array_equal(np.asarray(lax_out), np.asarray(pl_out))
    # untouched rows bitwise-preserved, target rows replaced
    np.testing.assert_array_equal(np.asarray(lax_out[0, 1:]),
                                  np.asarray(cache[0, 1:]))
    np.testing.assert_array_equal(np.asarray(lax_out[2, 4]),
                                  np.asarray(new[2, 0]))


# -- flash attention ------------------------------------------------------------------

def _fa_ref_4d(q, k, v, **kw):
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], hd)
    ref = flash_attention_ref(qf, kf, vf, **kw)
    return ref.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (9, 3)])
@pytest.mark.parametrize("s", [256, 512])
def test_flash_gqa_causal(h, kvh, s):
    hd, b = 64, 2
    q = jax.random.normal(rng(19), (b, s, h, hd), jnp.float32)
    k = jax.random.normal(rng(20), (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(rng(21), (b, s, kvh, hd), jnp.float32)
    kw = dict(causal=True, scale=1.0 / math.sqrt(hd))
    out = flash_attention(q, k, v, block_q=128, block_k=128,
                          interpret=True, **kw)
    assert_allclose(out, _fa_ref_4d(q, k, v, **kw), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window,softcap", [(128, None), (None, 30.0),
                                            (64, 50.0)])
def test_flash_window_softcap(window, softcap):
    b, s, h, kvh, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(rng(22), (b, s, h, hd), jnp.float32)
    k = jax.random.normal(rng(23), (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(rng(24), (b, s, kvh, hd), jnp.float32)
    kw = dict(causal=True, window=window, softcap=softcap,
              scale=1.0 / math.sqrt(hd))
    out = flash_attention(q, k, v, block_q=128, block_k=128,
                          interpret=True, **kw)
    assert_allclose(out, _fa_ref_4d(q, k, v, **kw), rtol=3e-4, atol=3e-4)


def test_flash_bf16():
    b, s, h, kvh, hd = 1, 256, 4, 4, 64
    q = jax.random.normal(rng(25), (b, s, h, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(rng(26), (b, s, kvh, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(rng(27), (b, s, kvh, hd)).astype(jnp.bfloat16)
    kw = dict(causal=True, scale=1.0 / math.sqrt(hd))
    out = flash_attention(q, k, v, interpret=True, **kw)
    ref = _fa_ref_4d(q, k, v, **kw)
    assert out.dtype == jnp.bfloat16
    assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                    rtol=0.05, atol=0.05)


def test_flash_grad_matches_ref():
    b, s, h, kvh, hd = 1, 256, 4, 2, 32
    q = jax.random.normal(rng(28), (b, s, h, hd), jnp.float32)
    k = jax.random.normal(rng(29), (b, s, kvh, hd), jnp.float32)
    v = jax.random.normal(rng(30), (b, s, kvh, hd), jnp.float32)
    kw = dict(causal=True, scale=1.0 / math.sqrt(hd))

    def f_pallas(q_):
        return (flash_attention(q_, k, v, interpret=True, **kw) ** 2).sum()

    def f_ref(q_):
        return (_fa_ref_4d(q_, k, v, **kw) ** 2).sum()

    g1 = jax.grad(f_pallas)(q)
    g2 = jax.grad(f_ref)(q)
    assert_allclose(g1, g2, rtol=1e-3, atol=1e-3)
