"""Chunked-prefill kernel family: parity gates for serve admission.

Same three-tier structure as the flash-decode gates
(tests/test_decode_attention.py), tightest first:

  * kernel-level: the Pallas kernel (interpret mode) must match the
    blockwise ``ref.py`` oracle *bit-exactly* — the kernel only adds
    cache-block skipping, which is a bit-neutral update (see ref.py),
    so any fp difference is a real bug, not tolerance noise.  The
    fused-lax fallback computes one dense masked softmax over
    [prefix ++ chunk], so it matches within fp32 reassociation.
  * layer-level: ``prefill_chunk_self_attention`` resumed chunk by
    chunk must reproduce a single whole-sequence ``attention`` call —
    written cache rows bitwise (same projections of the same inputs),
    outputs to fp tolerance — including ring caches whose chunk
    queries trail the newest prefix position (the window mask decode
    never needs).
  * ops-level: dispatch validation, scalar == vector offsets bitwise,
    v_width aliasing (the MLA latent cache).

Model- and engine-level chunked-vs-whole gates live in
tests/test_serve_chunked.py.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels.prefill_attention import (prefill_attention,
                                             prefill_attention_lax,
                                             prefill_attention_pallas,
                                             prefill_attention_ref)


def rng(i):
    return jax.random.PRNGKey(i)


def make_inputs(key, b, kvh, g, hdq, hdv, c, t, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, kvh, t, g, hdq)).astype(dtype)
    kx = jax.random.normal(ks[1], (b, t, kvh, hdq)).astype(dtype)
    vx = jax.random.normal(ks[2], (b, t, kvh, hdv)).astype(dtype)
    kc = jax.random.normal(ks[3], (b, c, kvh, hdq)).astype(dtype)
    vc = jax.random.normal(ks[4], (b, c, kvh, hdv)).astype(dtype)
    return q, kx, vx, kc, vc


# -- kernel-level: bit-exact vs the blockwise oracle ---------------------------

@pytest.mark.parametrize("kvh,g", [(4, 1), (2, 4), (1, 8)])  # G = 1, 4, H
@pytest.mark.parametrize("ring,window", [(False, None), (True, 24),
                                         (True, 7)])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_prefill_kernel_bit_exact_vs_ref(kvh, g, ring, window, softcap):
    """One (B,) offsets vector covers every resume class at once: cold
    start (offset 0 — no cache block valid), tiny prefix, mid, full,
    and (ring) wrapped-past-capacity."""
    b, hdq, hdv, c, t, bk = 5, 32, 24, 64, 16, 16
    q, kx, vx, kc, vc = make_inputs(rng(1), b, kvh, g, hdq, hdv, c, t)
    offs = jnp.array([0, 1, c // 2, c - t,
                      c + c // 2 if ring else c - 1], jnp.int32)
    kw = dict(ring=ring, window=window, softcap=softcap,
              scale=1.0 / math.sqrt(hdq), block_k=bk)
    ref = prefill_attention_ref(q, kx, vx, kc, vc, offs, **kw)
    pal = prefill_attention_pallas(q, kx, vx, kc, vc, offs,
                                   interpret=True, **kw)
    lax = prefill_attention_lax(q, kx, vx, kc, vc, offs, **kw)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))
    assert_allclose(np.asarray(lax), np.asarray(ref), rtol=2e-6, atol=2e-6)
    assert np.isfinite(np.asarray(ref)).all()


def test_prefill_kernel_single_block_and_odd_sizes():
    # single-block cache/chunk (block_k >= size) and sizes that force
    # the gcd fallback blocks (c=40, t=6 with block_k=16 -> 8 / 2)
    for c, t, bk in [(32, 8, 128), (40, 6, 16)]:
        q, kx, vx, kc, vc = make_inputs(rng(2), 2, 2, 3, 16, 16, c, t)
        offs = jnp.array([c // 3, c - t], jnp.int32)
        kw = dict(ring=True, window=c // 2, softcap=None, scale=0.25,
                  block_k=bk)
        ref = prefill_attention_ref(q, kx, vx, kc, vc, offs, **kw)
        pal = prefill_attention_pallas(q, kx, vx, kc, vc, offs,
                                       interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))


def test_prefill_kernel_bf16():
    q, kx, vx, kc, vc = make_inputs(rng(3), 2, 2, 4, 32, 32, 64, 8,
                                    dtype=jnp.bfloat16)
    offs = jnp.array([5, 63], jnp.int32)
    kw = dict(ring=False, window=None, softcap=None,
              scale=1.0 / math.sqrt(32))
    ref = prefill_attention_ref(q, kx, vx, kc, vc, offs, **kw)
    pal = prefill_attention_pallas(q, kx, vx, kc, vc, offs,
                                   interpret=True, **kw)
    assert pal.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(pal, np.float32),
                                  np.asarray(ref, np.float32))


def test_prefill_kernel_mixed_cache_dtype():
    """The serve path reads a bf16 cache with fp32 chunk activations —
    both impls must consume each operand in its own dtype."""
    q, kx, vx, kc, vc = make_inputs(rng(4), 2, 2, 2, 16, 16, 32, 8)
    kc, vc = kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16)
    offs = jnp.array([3, 17], jnp.int32)
    kw = dict(scale=0.25)
    ref = prefill_attention_ref(q, kx, vx, kc, vc, offs, **kw)
    pal = prefill_attention_pallas(q, kx, vx, kc, vc, offs,
                                   interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))


# -- ops-level -----------------------------------------------------------------

def test_prefill_ops_scalar_equals_vector():
    b, t, h, kvh, hd, c = 3, 8, 8, 2, 32, 64
    q = jax.random.normal(rng(5), (b, t, h, hd), jnp.float32)
    kx = jax.random.normal(rng(6), (b, t, kvh, hd), jnp.float32)
    kc = jax.random.normal(rng(7), (b, c, kvh, hd), jnp.float32)
    for impl in ("lax", "pallas_interpret"):
        o_s = prefill_attention(q, kx, kx, kc, kc, 17, impl=impl,
                                scale=0.2)
        o_v = prefill_attention(q, kx, kx, kc, kc,
                                jnp.full((b,), 17, jnp.int32),
                                impl=impl, scale=0.2)
        np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_v))
        assert o_s.shape == (b, t, h, hd)


def test_prefill_ops_v_width_alias():
    """MLA passes the concatenated [latent | rope] rows as both K and V
    with v_width — must equal attending explicitly sliced values, on
    both dispatch paths, under jit."""
    b, t, h, c, r, rope = 2, 8, 4, 40, 32, 16
    q = jax.random.normal(rng(8), (b, t, h, r + rope), jnp.float32)
    kvx = jax.random.normal(rng(9), (b, t, 1, r + rope), jnp.float32)
    kvc = jax.random.normal(rng(10), (b, c, 1, r + rope), jnp.float32)
    offs = jnp.array([0, c - t], jnp.int32)
    explicit = prefill_attention(q, kvx, kvx[..., :r], kvc, kvc[..., :r],
                                 offs, impl="lax", scale=0.1)
    for impl in ("lax", "pallas_interpret"):
        alias = jax.jit(
            lambda q, kvx, kvc, o, i=impl: prefill_attention(
                q, kvx, kvx, kvc, kvc, o, impl=i, scale=0.1,
                v_width=r))(q, kvx, kvc, offs)
        assert alias.shape == (b, t, h, r)
        tol = dict(rtol=0, atol=0) if impl == "lax" else \
            dict(rtol=2e-6, atol=2e-6)
        assert_allclose(np.asarray(alias), np.asarray(explicit), **tol)


def test_prefill_ops_validation():
    q = jnp.zeros((2, 8, 4, 16))
    kx = jnp.zeros((2, 8, 2, 16))
    kc = jnp.zeros((2, 32, 2, 16))
    with pytest.raises(ValueError, match="chunk keys"):
        prefill_attention(q, kc, kc, kc, kc, 0, impl="lax")
    with pytest.raises(ValueError, match="divisible"):
        prefill_attention(jnp.zeros((2, 8, 3, 16)), kx, kx, kc, kc, 0,
                          impl="lax")
    with pytest.raises(ValueError, match="window"):
        prefill_attention(q, kx, kx, kc, kc, 0, ring=True, impl="lax")
    with pytest.raises(ValueError, match="window"):
        prefill_attention(q, kx, kx, kc, kc, 0, window=8, impl="lax")
    with pytest.raises(ValueError, match="unknown prefill_attention"):
        prefill_attention(q, kx, kx, kc, kc, 0, impl="nope")


def test_prefill_dispatch_env_override(monkeypatch):
    from repro.kernels.prefill_attention import ops
    monkeypatch.setenv("PMT_PREFILL_ATTENTION_DISPATCH", "pallas_interpret")
    assert ops._resolve("auto") == "pallas_interpret"
    assert ops._resolve("lax") == "lax"          # explicit beats env
    monkeypatch.delenv("PMT_PREFILL_ATTENTION_DISPATCH")
    assert ops._resolve("auto") in ("pallas", "lax")


# -- layer-level: chunked resume == whole-sequence attention -------------------

@pytest.mark.parametrize("window", [None, 16, 5])
def test_layer_chunked_prefill_matches_whole(window):
    """Drive ``prefill_chunk_self_attention`` chunk by chunk over a
    prompt (fp32 cache so quantization cannot hide drift) and compare
    against one whole-sequence ``attention`` call: written cache rows
    must match bitwise, outputs to fp tolerance.  Covers full caches
    and ring caches shorter than the prompt."""
    import dataclasses

    from repro import configs
    from repro.models import attention as A
    from repro.sharding.specs import split_params

    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32", sliding_window=window)
    p, _ = split_params(A.init_attention(rng(0), cfg))
    b, s, chunk, max_len = 2, 24, 8, 32
    x = jax.random.normal(rng(1), (b, s, cfg.d_model), jnp.float32) * 0.3

    # whole-sequence reference (dense attention + prefill cache build)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q, k, v = A.project_qkv(cfg, p, x, pos)
    o_ref = A.attention(cfg, q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        window=window, impl="dense")
    out_ref = A.output_proj(p, o_ref)
    cache_ref = A.prefill_kv_cache(cfg, k, v, max_len, window=window,
                                   dtype=jnp.float32)

    # chunked resume
    size = min(max_len, window) if window else max_len
    cache = {"k": jnp.zeros((b, size, cfg.num_kv_heads, cfg.head_dim),
                            jnp.float32)}
    cache["v"] = cache["k"]
    outs = []
    for off in range(0, s, chunk):
        o, cache = A.prefill_chunk_self_attention(
            cfg, p, x[:, off:off + chunk], cache,
            jnp.asarray(off, jnp.int32), jnp.asarray(chunk, jnp.int32),
            window=window)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1)

    assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=2e-5,
                    atol=2e-5)
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache[leaf]),
                                      np.asarray(cache_ref[leaf]))


def test_layer_partial_final_chunk_pads_masked():
    """A right-padded final chunk must leave ring caches exactly as a
    pad-free run does: pad writes would wrap onto valid older
    positions."""
    import dataclasses

    from repro import configs
    from repro.models import attention as A
    from repro.sharding.specs import split_params

    window = 8
    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32", sliding_window=window)
    p, _ = split_params(A.init_attention(rng(0), cfg))
    b, plen, chunk = 1, 13, 8
    x = jax.random.normal(rng(2), (b, plen, cfg.d_model), jnp.float32) * 0.3

    def run(x_padded, valid_lens):
        cache = {"k": jnp.zeros((b, window, cfg.num_kv_heads,
                                 cfg.head_dim), jnp.float32)}
        cache["v"] = cache["k"]
        for i, off in enumerate(range(0, x_padded.shape[1], chunk)):
            _, cache = A.prefill_chunk_self_attention(
                cfg, p, x_padded[:, off:off + chunk], cache,
                jnp.asarray(off, jnp.int32),
                jnp.asarray(valid_lens[i], jnp.int32), window=window)
        return cache

    # padded: 13 -> 16, final chunk has 5 valid tokens + 3 pads whose
    # ring slots (13..15) % 8 = 5..7 hold positions 5..7 — in-window!
    x_pad = jnp.concatenate(
        [x, jnp.full((b, 16 - plen, cfg.d_model), 7.7, jnp.float32)],
        axis=1)
    cache_pad = run(x_pad, [chunk, plen - chunk])
    # reference: exact-length chunks, no pads (chunk == remaining)
    cache_exact = run(x, [chunk, plen - chunk])
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache_pad[leaf]),
                                      np.asarray(cache_exact[leaf]))
