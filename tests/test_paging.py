"""Host-side paged-KV bookkeeping: allocator + radix prefix cache gates.

``PagePool``: alloc/free/refcount invariants under randomized churn —
page 0 never handed out, all-or-nothing allocation, double-free and
free-page-ref rejected, conservation of pages (free + live == capacity)
after arbitrary interleavings.

``RadixPrefixCache``: randomized insert/match/evict runs checked against
a brute-force oracle (a dict of every page-aligned prefix ever
inserted): match returns exactly the oracle's longest cached prefix and
the oracle's pages for it, refcounts account for every tree node plus
every outstanding match, and eviction keeps shared pages alive until
the last holder releases.
"""
import random

import pytest

from repro.serve.paging import SCRATCH_PAGE, PagePool, RadixPrefixCache


def test_scratch_page_reserved():
    pool = PagePool(num_pages=4, page_size=8)
    assert pool.total_pages == 3
    got = pool.alloc(3)
    assert got is not None and SCRATCH_PAGE not in got
    assert pool.alloc(1) is None            # exhausted, not scratch-grabbing
    assert pool.refcount(SCRATCH_PAGE) == 1


def test_alloc_is_all_or_nothing():
    pool = PagePool(num_pages=5, page_size=4)
    assert pool.alloc(5) is None
    assert pool.free_pages == 4             # failed alloc left nothing behind
    pages = pool.alloc(4)
    assert sorted(pages) == [1, 2, 3, 4]
    assert pool.free_pages == 0 and pool.used_pages == 4


def test_refcount_lifecycle():
    pool = PagePool(num_pages=3, page_size=4)
    (p,) = pool.alloc(1)
    pool.ref([p])
    assert pool.refcount(p) == 2
    assert pool.release([p]) == 0           # still one holder
    assert pool.release([p]) == 1           # now actually freed
    with pytest.raises(ValueError):
        pool.release([p])                   # double free
    with pytest.raises(ValueError):
        pool.ref([p])                       # can't revive a free page


def test_pool_churn_conserves_pages():
    rng = random.Random(0)
    pool = PagePool(num_pages=33, page_size=4)
    live = []                               # (page, refs) we still hold
    for _ in range(2000):
        action = rng.random()
        if action < 0.4:
            got = pool.alloc(rng.randint(1, 5))
            if got is not None:
                live.extend((p, 1) for p in got)
        elif action < 0.6 and live:
            i = rng.randrange(len(live))
            p, r = live[i]
            pool.ref([p])
            live[i] = (p, r + 1)
        elif live:
            i = rng.randrange(len(live))
            p, r = live[i]
            pool.release([p])
            if r == 1:
                live.pop(i)
            else:
                live[i] = (p, r - 1)
        held = {p for p, _ in live}
        assert pool.free_pages + len(held) == pool.total_pages
        for p, r in live:
            pass
    for p, r in live:
        pool.release([p] * r)
    assert pool.free_pages == pool.total_pages


# ---------------------------------------------------------------------------
# radix prefix cache
# ---------------------------------------------------------------------------


def _tok(rng, n, vocab=7):
    return [rng.randrange(vocab) for _ in range(n)]


def test_radix_match_and_insert_basic():
    pool = PagePool(num_pages=32, page_size=4)
    tree = RadixPrefixCache(pool)
    toks = list(range(10))                  # 2 full pages + 2 spare tokens
    pages = pool.alloc(3)
    adopted = tree.insert(toks, pages)
    assert adopted == 2                     # only full pages adopted
    assert pool.refcount(pages[0]) == 2 and pool.refcount(pages[2]) == 1

    n, got = tree.match(toks)
    assert n == 8 and got == pages[:2]
    assert pool.refcount(pages[0]) == 3     # tree + us
    pool.release(got)

    n, got = tree.match(toks[:4] + [99, 99, 99, 99])
    assert n == 4 and got == pages[:1]
    pool.release(got)

    n, got = tree.match([99] * 8)
    assert n == 0 and got == []
    assert tree.lookups == 3 and tree.hits == 2


def test_radix_insert_keeps_existing_page_on_duplicate():
    pool = PagePool(num_pages=16, page_size=2)
    tree = RadixPrefixCache(pool)
    a = pool.alloc(1)
    b = pool.alloc(1)
    assert tree.insert([1, 2], a) == 1
    assert tree.insert([1, 2], b) == 0      # same content: existing page wins
    n, got = tree.match([1, 2])
    assert n == 2 and got == a
    pool.release(got)


def test_radix_eviction_respects_live_refs():
    pool = PagePool(num_pages=4, page_size=2)
    tree = RadixPrefixCache(pool)
    pages = pool.alloc(2)
    tree.insert([1, 2, 3, 4], pages)
    n, held = tree.match([1, 2, 3, 4])      # a "live request" shares both
    assert n == 4
    pool.release(pages)                     # original owner retires
    # tree holds both, the live request holds both -> nothing freed yet
    assert pool.free_pages == 1

    # ask for more than free: eviction drops tree refs, but shared pages
    # stay out of the free list until the live request releases them.
    tree.evict_for(3)
    assert tree.node_count == 0 and tree.evictions == 2
    assert pool.free_pages == 1             # still held by the request
    pool.release(held)
    assert pool.free_pages == 3


def test_radix_lru_order():
    pool = PagePool(num_pages=8, page_size=1)
    tree = RadixPrefixCache(pool)
    pa = pool.alloc(1)
    pb = pool.alloc(1)
    tree.insert([1], pa)
    tree.insert([2], pb)
    n, got = tree.match([1])                # touch [1]: [2] is now LRU
    pool.release(got)
    tree.evict_lru(1)
    assert tree.match([2])[0] == 0          # evicted
    n, got = tree.match([1])
    assert n == 1
    pool.release(got)


def test_radix_randomized_against_oracle():
    rng = random.Random(7)
    ps = 4
    pool = PagePool(num_pages=64, page_size=ps)
    tree = RadixPrefixCache(pool)
    oracle = {}                             # prefix tuple -> page id
    outstanding = []                        # page lists we must release

    def oracle_match(toks):
        pages = []
        for i in range(0, len(toks) - ps + 1, ps):
            page = oracle.get(tuple(toks[:i + ps]))
            if page is None:
                break
            pages.append(page)
        return pages

    def prune_oracle():
        # drop evicted prefixes (and their extensions) from the oracle
        live = set()

        def walk(node, prefix):
            for key, child in node.children.items():
                live.add(prefix + key)
                walk(child, prefix + key)

        walk(tree.root, ())
        return {k: v for k, v in oracle.items() if k in live}

    for step in range(300):
        toks = _tok(rng, rng.randrange(0, 4 * ps + 2), vocab=3)
        action = rng.random()
        if action < 0.45:
            n_pages = len(toks) // ps
            got = pool.alloc(n_pages)
            if got is None:
                tree.evict_for(n_pages)
                oracle = prune_oracle()
                got = pool.alloc(n_pages)
            if got is None:
                continue
            tree.insert(toks, got)
            if n_pages:
                # read the tree's actual pages back (duplicates kept the
                # pre-existing page) and mirror them into the oracle
                n, in_tree = tree.match(toks[:n_pages * ps])
                assert n == n_pages * ps
                for i, page in enumerate(in_tree):
                    oracle[tuple(toks[:(i + 1) * ps])] = page
                pool.release(in_tree)
            pool.release(got)
        elif action < 0.85:
            n, got = tree.match(toks)
            expect = oracle_match(toks)
            assert n == len(expect) * ps
            assert got == expect
            if got and rng.random() < 0.5:
                outstanding.append(got)
            elif got:
                pool.release(got)
        else:
            before = tree.node_count
            evicted = tree.evict_lru(rng.randint(1, 3))
            assert tree.node_count == before - evicted
            oracle = prune_oracle()
        assert tree.node_count == len(oracle)
    for got in outstanding:
        pool.release(got)
    tree.evict_for(pool.total_pages)
    assert pool.free_pages == pool.total_pages
