"""Paged-KV serve engine: byte-parity, prefix reuse, and pool gates.

The load-bearing property is *layout transparency*: the paged engine —
page pools, per-request page tables, radix prefix cache, batched chunk
admissions — must generate exactly the tokens the contiguous engine
generates for the same requests.  Block-paging changes where KV bytes
live, never what the model computes.

Prefix-reuse gate: re-serving prompts whose pages sit in the radix tree
must produce the same tokens as the cold run.  This is exact when the
resume offset lands on the cold run's chunk grid, which the tests force
with ``kv_page_size == prefill_chunk`` for the MoE arch (off-grid
resumes reorder float reductions by ~1 ulp, which can flip near-tied
router top-k choices in random-init reduced models — dense archs are
gated off-grid precisely because they don't amplify).

Pool gates: admissions wait (not fail) on an exhausted pool, the
engine refuses pools smaller than one request, and the stats() cache
gauges stay consistent with the pool/radix state they mirror.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine

ARCHS = ["smollm-135m", "gemma2-27b", "deepseek-v3-671b"]


def _fp32(arch, **over):
    cfg = dataclasses.replace(configs.get_config(arch, reduced=True),
                              dtype="float32", **over)
    if cfg.moe is not None:
        # effectively dropless: capacity-limited token dropping depends
        # on batch composition, which differs between the engines and
        # runs being compared
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = _fp32(request.param, prefill_chunk=16)
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


@pytest.fixture(scope="module")
def smollm():
    cfg = _fp32("smollm-135m", prefill_chunk=16)
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def mk(cfg, n_req=5, seed=7, max_new=6):
    rng = np.random.default_rng(seed)
    plens = [13, 21, 9, 30, 17]
    return [Request(prompt=rng.integers(
        2, cfg.vocab_size - 1, size=(p,)).tolist(), max_new_tokens=max_new)
        for p, _ in zip(plens * 10, range(n_req))]


def outs(rs):
    return [r.out for r in rs]


# -- tentpole gate: paged == contiguous, exactly ----------------------------

def test_paged_matches_contiguous(arch_setup):
    """Same requests, same scheduler: the paged engine's token streams
    equal the contiguous engine's across the cache families (GQA,
    sliding-window, MLA latent) — including slot refills (5 requests
    through 2 slots) and batched multi-row chunk admissions."""
    arch, cfg, params = arch_setup
    rs = mk(cfg)
    eng_c = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        cache_dtype=jnp.float32)
    ref = outs(eng_c.generate([dataclasses.replace(r) for r in rs]))
    eng_p = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        kv_layout="paged", kv_page_size=8,
                        cache_dtype=jnp.float32)
    got = outs(eng_p.generate([dataclasses.replace(r) for r in rs]))
    assert got == ref, f"{arch}: paged diverged from contiguous"


def test_paged_pool_exhaustion_waits(smollm):
    """A pool that fits ~one request forces admissions to wait on page
    frees; every request still completes with contiguous-exact tokens."""
    cfg, params = smollm
    rs = mk(cfg)
    eng_c = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        cache_dtype=jnp.float32)
    ref = outs(eng_c.generate([dataclasses.replace(r) for r in rs]))
    eng_s = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        kv_layout="paged", kv_page_size=8,
                        kv_pool_pages=9, cache_dtype=jnp.float32)
    got = outs(eng_s.generate([dataclasses.replace(r) for r in rs]))
    assert got == ref
    st = eng_s.stats()["kv_cache"]
    assert st["pages_total"] == 9


# -- prefix-reuse gate ------------------------------------------------------

def test_prefix_hit_matches_cold(arch_setup):
    """Serving the same prompts twice: the warm run maps cached pages
    copy-free off the radix tree (hit tokens accrue) and generates the
    cold run's exact tokens.  ``kv_page_size == prefill_chunk`` keeps
    the MoE arch's resume on the cold chunk grid; the dense archs use a
    smaller page so off-grid resume is exercised too."""
    arch, cfg, params = arch_setup
    ps = 16 if arch == "deepseek-v3-671b" else 8
    rs = mk(cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      kv_layout="paged", kv_page_size=ps,
                      cache_dtype=jnp.float32)
    cold = outs(eng.generate([dataclasses.replace(r) for r in rs]))
    st0 = eng.stats()["kv_cache"]
    assert st0["prefix_hit_tokens"] == 0
    assert st0["pages_used"] > 0      # retired pages live on in the tree
    warm = outs(eng.generate([dataclasses.replace(r) for r in rs]))
    st1 = eng.stats()["kv_cache"]
    assert warm == cold, f"{arch}: prefix-hit run diverged from cold"
    assert st1["prefix_hit_tokens"] > 0
    assert st1["prefix_hits"] > 0
    assert 0.0 < st1["prefix_hit_rate"] <= 1.0


def test_prefix_cache_off_is_isolated(smollm):
    """``prefix_cache=False``: no pages survive retirement, reruns take
    no hits, tokens still match the cached engine's cold run."""
    cfg, params = smollm
    rs = mk(cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      kv_layout="paged", kv_page_size=8,
                      prefix_cache=False, cache_dtype=jnp.float32)
    a = outs(eng.generate([dataclasses.replace(r) for r in rs]))
    b = outs(eng.generate([dataclasses.replace(r) for r in rs]))
    assert a == b
    st = eng.stats()["kv_cache"]
    assert st["prefix_cache"] is False
    assert st["prefix_hit_tokens"] == 0
    assert st["pages_used"] == 0      # everything back on the free list


def test_radix_eviction_under_pool_pressure(smollm):
    """A pool too small to hold every retired prompt forces
    ``evict_for`` to reclaim LRU tree pages at admission; serving
    distinct prompts through it stays correct and evictions surface in
    the gauges."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    rs = [Request(prompt=rng.integers(2, cfg.vocab_size - 1,
                                      size=(24,)).tolist(),
                  max_new_tokens=4) for _ in range(6)]
    eng_c = ServeEngine(cfg, params, batch_size=2, max_len=64,
                        cache_dtype=jnp.float32)
    ref = outs(eng_c.generate([dataclasses.replace(r) for r in rs]))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      kv_layout="paged", kv_page_size=8, kv_pool_pages=8,
                      cache_dtype=jnp.float32)
    got = outs(eng.generate([dataclasses.replace(r) for r in rs]))
    assert got == ref
    assert eng.stats()["kv_cache"]["prefix_evictions"] > 0


# -- gauges and lifecycle ---------------------------------------------------

def test_cache_gauges_consistent(smollm):
    """stats()["kv_cache"] mirrors the pool: used + free == total, and
    a fresh engine starts fully free."""
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      kv_layout="paged", kv_page_size=8,
                      cache_dtype=jnp.float32)
    st = eng.stats()
    assert st["kv_layout"] == "paged"
    kc = st["kv_cache"]
    assert kc["page_size"] == 8
    assert kc["pages_used"] == 0
    assert kc["pages_free"] == kc["pages_total"] == 2 * (64 // 8)
    eng.generate(mk(cfg, n_req=3))
    kc = eng.stats()["kv_cache"]
    assert kc["pages_used"] + kc["pages_free"] == kc["pages_total"]
    assert kc["saved_prefill_joules"] >= 0.0


def test_contiguous_engine_reports_no_pool(smollm):
    # contiguous engines report the dtype/footprint gauges but none of
    # the pool/radix keys that only exist in the paged layout
    cfg, params = smollm
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      cache_dtype=jnp.float32)
    st = eng.stats()
    assert st["kv_layout"] == "contiguous"
    kc = st["kv_cache"]
    assert kc["cache_dtype"] == "float32"
    assert kc["bytes_per_token"] > 0
    for key in ("pages_total", "prefix_hits", "pool_wait_events"):
        assert key not in kc


def test_paged_validation(smollm):
    cfg, params = smollm
    with pytest.raises(ValueError, match="kv_layout"):
        ServeEngine(cfg, params, batch_size=2, max_len=64,
                    kv_layout="mystery")
    # pool must fit at least one full request's pages
    with pytest.raises(ValueError, match="pool"):
        ServeEngine(cfg, params, batch_size=2, max_len=64,
                    kv_layout="paged", kv_page_size=8, kv_pool_pages=7)
    # paged requires chunked continuous admission
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, batch_size=2, max_len=64,
                    kv_layout="paged", mode="wave")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, batch_size=2, max_len=64,
                    kv_layout="paged", prefill_chunk=0)


def test_paged_admission_batching(smollm):
    """Queued admissions prefill *together*: with both slots admitting
    simultaneously, one batched chunk dispatch advances both rows, so
    the prefill dispatch count stays well under one-per-request-chunk."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    rs = [Request(prompt=rng.integers(2, cfg.vocab_size - 1,
                                      size=(32,)).tolist(),
                  max_new_tokens=2) for _ in range(4)]
    eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                      kv_layout="paged", kv_page_size=8,
                      cache_dtype=jnp.float32)
    calls = {"n": 0}
    orig = eng._paged_prefill_chunk_fn

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    eng._paged_prefill_chunk_fn = counting
    eng.generate(rs)
    solo_chunks = sum(-(-len(r.prompt) // cfg.prefill_chunk) for r in rs)
    assert calls["n"] < solo_chunks, (
        f"{calls['n']} batched dispatches vs {solo_chunks} per-request "
        "chunks — admissions are not sharing dispatches")
    assert all(len(r.out) == 2 for r in rs)
