"""Telemetry plane: recorder aggregation, HTTP/SSE endpoints, and the
exporter plumbing underneath them.

The end-to-end gates: a live engine's per-request energy is readable
over plain ``urllib`` against an ephemeral port while the run is still
warm (zero added dependencies), the ``/requests`` payload satisfies the
``prefill_joules + decode_joules == joules`` invariant, and the raw
``RegionRecord``\\ s survive the exporter -> recorder -> HTTP -> client
round trip bit-faithfully (``as_json``/``from_json``).

The plumbing gates below them: ``MemoryExporter`` stays consistent
under concurrent emit + subscribe/unsubscribe and drops (never
propagates) a raising subscriber, and ``read_jsonl`` skips a truncated
trailing line instead of losing the whole export.
"""
import dataclasses
import json
import threading
import time
import urllib.error
import urllib.request
import warnings

import pytest

import repro.core as pmt
from repro.core.backends.dummy import DummySensor
from repro.core.export import MemoryExporter, RegionRecord, read_jsonl
from repro.telemetry import (PowerRecorder, SSESubscriber, TelemetryServer,
                             format_sse)


@pytest.fixture(scope="module")
def smollm_serve():
    import jax
    from repro import configs
    from repro.models import model as M
    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32")
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def rec_for(path, joules=1.0, tokens=None, start=0.0, end=1.0):
    return RegionRecord(path=path, label=path.rsplit("/", 1)[-1], depth=0,
                        sensor="dummy", kind="modeled", start_s=start,
                        end_s=end, seconds=end - start, joules=joules,
                        watts=joules / max(end - start, 1e-9),
                        tokens=tokens)


def get_json(url):
    with urllib.request.urlopen(url, timeout=5.0) as resp:
        assert resp.status == 200
        return json.loads(resp.read().decode())


# -- recorder ---------------------------------------------------------------

class TestPowerRecorder:
    def test_mean_watts_windowing(self):
        rec = PowerRecorder()
        for i in range(10):
            rec.add_watts("dummy", float(i), 100.0 if i >= 5 else 10.0)
        # window covering only the 100 W tail
        assert rec.mean_watts(4.0) == pytest.approx(100.0)
        # window spanning everything
        assert rec.mean_watts(100.0) == pytest.approx(55.0)
        assert rec.mean_watts(1.0, backend="nope") is None

    def test_mean_watts_sums_backends(self):
        rec = PowerRecorder()
        rec.add_watts("a", 1.0, 30.0)
        rec.add_watts("b", 1.0, 12.0)
        assert rec.mean_watts(5.0) == pytest.approx(42.0)
        assert rec.mean_watts(5.0, backend="a") == pytest.approx(30.0)

    def test_nonfinite_watts_skipped(self):
        rec = PowerRecorder()
        rec.add_watts("dummy", 0.0, float("nan"))
        rec.add_watts("dummy", 1.0, float("inf"))
        assert rec.mean_watts(10.0) is None

    def test_bounded_rings_count_total(self):
        rec = PowerRecorder(record_capacity=4)
        for i in range(10):
            rec.on_record(rec_for(f"r{i}"))
        assert len(rec.records()) == 4
        st = rec.stats()
        assert st["records"] == 10 and st["records_retained"] == 4

    def test_subscriber_fanout_and_drop_on_raise(self):
        rec = PowerRecorder()
        got = []
        rec.subscribe(got.append)

        def bad(r):
            raise RuntimeError("boom")

        rec.subscribe(bad)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            rec.on_record(rec_for("a"))
            rec.on_record(rec_for("b"))
        assert [r.path for r in got] == ["a", "b"]
        assert any("subscriber dropped" in str(x.message) for x in w)
        assert rec.stats()["subscribers"] == 1

    def test_request_energy_aggregation(self):
        rec = PowerRecorder()
        rec.on_record(rec_for("serve/req3", joules=10.0, tokens=5))
        rec.on_record(rec_for("serve/req3/prefill", joules=6.0))
        rec.on_record(rec_for("serve/req3/decode", joules=4.0))
        rec.on_record(rec_for("serve/batch0", joules=99.0))  # not a request
        energy = rec.request_energy()
        assert set(energy) == {3}
        d = energy[3]
        assert d["joules"] == pytest.approx(10.0)
        assert d["prefill_joules"] + d["decode_joules"] \
            == pytest.approx(d["joules"])
        assert d["tokens"] == 5
        assert d["j_per_token"] == pytest.approx(2.0)
        assert len(d["records"]) == 3

    def test_attach_polls_session_watts(self):
        sensor = DummySensor(watts=42.0)
        with pmt.Session([sensor], pool=pmt.SensorPool(),
                         period_s=0.002) as sess:
            with PowerRecorder(poll_period_s=0.01).attach(sess) as rec:
                with sess.region("work"):
                    time.sleep(0.05)
                sess.flush()
                rec.poll_once()
                series = rec.watts_series("dummy")["dummy"]
                assert series, "no watts polled off the ring sampler"
                assert all(w == pytest.approx(42.0) for _t, w in series)
                assert rec.mean_watts(1.0) == pytest.approx(42.0)
                assert any(r.path == "work" for r in rec.records())

    def test_stats_providers_merge_and_capture_errors(self):
        rec = PowerRecorder()
        rec.add_stats_provider(lambda: {"extra": 7})
        rec.add_stats_provider(lambda: 1 / 0)
        st = rec.stats()
        assert st["extra"] == 7
        assert any("ZeroDivisionError" in e
                   for e in st["stats_provider_errors"])


# -- SSE plumbing -----------------------------------------------------------

class TestSSE:
    def test_format_sse_framing(self):
        msg = format_sse("a\nb", event="record", event_id="7")
        assert msg == b"id: 7\nevent: record\ndata: a\ndata: b\n\n"
        assert format_sse("") == b"data: \n\n"

    def test_subscriber_drops_oldest_never_blocks(self):
        sub = SSESubscriber(maxlen=3)
        for i in range(6):
            sub.put(i)
        assert sub.dropped == 3
        assert [sub.get(0.01) for _ in range(3)] == [3, 4, 5]
        assert sub.get(0.01) is None    # timeout, not a hang


# -- HTTP endpoints ---------------------------------------------------------

@pytest.fixture()
def served_recorder():
    rec = PowerRecorder()
    rec.add_watts("dummy", 1.0, 50.0)
    rec.add_watts("dummy", 2.0, 70.0)
    rec.on_record(rec_for("serve/req0", joules=9.0, tokens=3))
    rec.on_record(rec_for("serve/req0/prefill", joules=5.0))
    rec.on_record(rec_for("serve/req0/decode", joules=4.0))
    with TelemetryServer(rec, sse_keepalive_s=0.05) as srv:
        yield rec, srv
    rec.close()


class TestTelemetryServer:
    def test_index_and_404(self, served_recorder):
        _rec, srv = served_recorder
        idx = get_json(srv.url + "/")
        assert "/timeline" in idx["endpoints"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/nope", timeout=5.0)
        assert ei.value.code == 404

    def test_timeline_params(self, served_recorder):
        _rec, srv = served_recorder
        d = get_json(srv.url + "/timeline?window=5")
        assert d["series"]["dummy"] == [[1.0, 50.0], [2.0, 70.0]]
        assert d["window_mean_watts"] == pytest.approx(60.0)
        d = get_json(srv.url + "/timeline?since=1.5")
        assert d["series"]["dummy"] == [[2.0, 70.0]]
        assert get_json(srv.url + "/timeline?backend=nope")["series"] == {}
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/timeline?window=bogus",
                                   timeout=5.0)
        assert ei.value.code == 400

    def test_requests_invariant_and_roundtrip(self, served_recorder):
        rec, srv = served_recorder
        d = get_json(srv.url + "/requests")
        assert d["count"] == 1
        req = d["requests"]["0"]
        assert req["prefill_joules"] + req["decode_joules"] \
            == pytest.approx(req["joules"])
        # bit-faithful round trip: the HTTP payload carries the exact
        # as_json() strings, which from_json() must invert
        originals = {r.path: r for r in rec.records()
                     if r.path.startswith("serve/req")}
        for line in req["records"]:
            back = RegionRecord.from_json(line)
            assert back == originals[back.path]
            assert back.as_json() == line

    def test_stats_endpoint(self, served_recorder):
        _rec, srv = served_recorder
        st = get_json(srv.url + "/stats")
        assert st["records"] == 3
        assert st["watts_samples"] == 2

    def test_sse_stream_delivers_new_records(self, served_recorder):
        rec, srv = served_recorder
        req = urllib.request.Request(srv.url + "/stream")
        resp = urllib.request.urlopen(req, timeout=5.0)
        assert resp.headers["Content-Type"] == "text/event-stream"
        lines = [resp.readline() for _ in range(3)]     # hello event
        assert lines[0] == b"event: hello\n"
        fresh = rec_for("serve/req1", joules=2.5, tokens=1)
        rec.on_record(fresh)
        deadline = time.monotonic() + 5.0
        data = None
        while time.monotonic() < deadline:
            line = resp.readline()
            if line.startswith(b"data: ") and b"serve/req1" in line:
                data = line[len(b"data: "):-1].decode()
                break
        assert data is not None, "record never arrived on the SSE stream"
        assert RegionRecord.from_json(data) == fresh
        resp.close()

    def test_close_is_idempotent(self, served_recorder):
        _rec, srv = served_recorder
        srv.close()
        srv.close()


# -- end-to-end: engine -> exporter -> recorder -> HTTP ---------------------

def test_serve_engine_requests_over_http(smollm_serve):
    """The ISSUE invariant, end to end on a live engine: per-request
    prefill + decode joules equal the request total as seen through
    ``/requests``, and the round-tripped records match the exporter's
    bit for bit."""
    cfg, params = smollm_serve
    from repro.serve.engine import Request, ServeEngine
    sensor = DummySensor(watts=100.0)
    with pmt.Session([sensor], pool=pmt.SensorPool(),
                     period_s=0.002) as sess:
        mem = sess.add_exporter(MemoryExporter())
        with PowerRecorder(poll_period_s=0.01).attach(
                sess, exporter=mem) as rec:
            eng = ServeEngine(cfg, params, batch_size=2, max_len=48,
                              session=sess, prefill_chunk=8)
            rec.add_stats_provider(eng.stats)
            reqs = [Request(prompt=[1, 2, 3], max_new_tokens=4),
                    Request(prompt=[4, 5, 6, 7, 8, 9], max_new_tokens=3)]
            done = eng.generate(reqs)
            sess.flush()
            rec.poll_once()
            with TelemetryServer(rec) as srv:
                d = get_json(srv.url + "/requests")
                st = get_json(srv.url + "/stats")
                tl = get_json(srv.url + "/timeline?window=60")
    assert d["count"] == len(done)
    exported = {r.as_json() for r in mem.records}
    for rid, req in d["requests"].items():
        assert req["prefill_joules"] > 0 and req["decode_joules"] > 0, rid
        assert req["prefill_joules"] + req["decode_joules"] \
            == pytest.approx(req["joules"], rel=0.02)
        for line in req["records"]:
            assert line in exported, "HTTP record not bit-identical"
            assert RegionRecord.from_json(line).as_json() == line
    # engine counters ride the /stats payload via the provider hook
    assert st["requests_admitted"] == len(done)
    assert "stall_p95_s" in st and "compile_counts" in st
    assert tl["series"]["dummy"], "no watts timeline over HTTP"
    assert tl["window_mean_watts"] == pytest.approx(100.0)


# -- MemoryExporter thread-safety (satellite) -------------------------------

class TestMemoryExporterConcurrency:
    def test_concurrent_emit_and_subscribe(self):
        exp = MemoryExporter()
        seen = []
        stop = threading.Event()
        errors = []

        def churn():
            # subscribe/unsubscribe continuously while emits run
            try:
                while not stop.is_set():
                    unsub = exp.subscribe(lambda r: None)
                    unsub()
            except Exception as e:          # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=churn) for _ in range(3)]
        for t in threads:
            t.start()
        exp.subscribe(seen.append)
        n = 400
        emitters = [threading.Thread(
            target=lambda lo: [exp.emit(rec_for(f"r{lo}/{i}"))
                               for i in range(n)], args=(k,))
            for k in range(2)]
        for t in emitters:
            t.start()
        for t in emitters:
            t.join()
        stop.set()
        for t in threads:
            t.join()
        assert not errors
        assert len(exp.records) == 2 * n
        assert len(seen) == 2 * n       # stable subscriber saw every emit

    def test_raising_subscriber_dropped_with_warning(self):
        exp = MemoryExporter()
        calls = []

        def bad(r):
            calls.append(r)
            raise ValueError("subscriber bug")

        exp.subscribe(bad)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            exp.emit(rec_for("a"))
            exp.emit(rec_for("b"))      # bad is gone: no second call
        assert len(calls) == 1
        assert any("subscriber dropped" in str(x.message) for x in w)
        assert [r.path for r in exp.records] == ["a", "b"]

    def test_unsubscribe_is_identity_based(self):
        exp = MemoryExporter()
        hits = []

        def cb(r):
            hits.append(r)

        u1 = exp.subscribe(cb)
        u2 = exp.subscribe(cb)          # same fn twice
        u1()
        exp.emit(rec_for("x"))
        assert len(hits) == 1           # one registration survives
        u2()
        exp.emit(rec_for("y"))
        assert len(hits) == 1


# -- read_jsonl robustness (satellite) --------------------------------------

class TestReadJsonl:
    def test_skips_truncated_trailing_line(self, tmp_path):
        good = rec_for("a", joules=3.0)
        p = tmp_path / "export.jsonl"
        p.write_text(good.as_json() + "\n"
                     + good.as_json()[: len(good.as_json()) // 2])
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = read_jsonl(p)
        assert out == [good]
        assert any("skipping unparseable" in str(x.message) for x in w)

    def test_skips_wrong_schema_line(self, tmp_path):
        good = rec_for("a")
        p = tmp_path / "export.jsonl"
        p.write_text('{"not": "a record"}\n' + good.as_json() + "\n")
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = read_jsonl(p)
        assert out == [good]
        assert len(w) == 1

    def test_strict_mode_still_raises(self, tmp_path):
        p = tmp_path / "export.jsonl"
        p.write_text("{broken\n")
        with pytest.raises(Exception):
            read_jsonl(p, strict=True)
