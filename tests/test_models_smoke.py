"""Per-arch smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.optim.optimizers import OptimizerConfig
from repro.train.steps import init_train_state, make_train_step

B, S = 2, 64


def make_batch(cfg, with_targets=True):
    batch = {"tokens": jnp.full((B, S), 3, jnp.int32)}
    if with_targets:
        batch["targets"] = jnp.ones((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.01 * jnp.ones(
            (B, configs.patch_len(cfg, S), cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = 0.01 * jnp.ones(
            (B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_no_nans(arch):
    cfg = configs.get_config(arch, reduced=True)
    params, axes = M.init_params(jax.random.PRNGKey(0), cfg)
    fwd = M.build_forward(cfg)
    hidden, aux = jax.jit(fwd)(params, make_batch(cfg, with_targets=False))
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_train_step_no_nans(arch):
    cfg = configs.get_config(arch, reduced=True)
    ocfg = OptimizerConfig(name=cfg.optimizer, warmup_steps=2, decay_steps=10)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    state, metrics = step(state, make_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.opt.step) == 1
    # params actually moved
    l0 = jax.tree.leaves(state.params)[0]
    assert np.isfinite(np.asarray(l0, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_scan_matches_loop(arch):
    """lax.scan over layer units == Python loop (roofline probes rely
    on this equivalence)."""
    import dataclasses
    cfg = configs.get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params, _ = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg, with_targets=False)
    h1, _ = jax.jit(M.build_forward(cfg))(params, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False,
                               unroll_time_chunks=True)
    h2, _ = jax.jit(M.build_forward(cfg2))(params, batch)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_param_counts_match_table():
    """Full-config analytic param counts are in the published ballpark."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "deepseek-v3-671b": (0.6e12, 0.75e12),
        "smollm-135m": (0.12e9, 0.15e9),
        "qwen3-0.6b": (0.4e9, 0.8e9),
        "olmo-1b": (1.0e9, 1.4e9),
        "gemma2-27b": (25e9, 30e9),   # 27.2B incl. the 1.18B tied embed
        "qwen2-vl-72b": (65e9, 80e9),
        # our mLSTM uses full (xLSTM-7B-style) q/k/v projections rather
        # than the 1.3B paper model's block-diagonal ones -> ~3.5B
        "xlstm-1.3b": (3.0e9, 4.0e9),
        "jamba-v0.1-52b": (45e9, 60e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"


def test_moe_active_params_much_smaller():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
