"""Paged kernel parity gates: bit-exact vs the ref.py twins.

Every paged kernel runs in interpret mode on CPU against its blockwise
oracle with *scrambled* page tables (physical pages deliberately out of
logical order, shared across no two rows) and must agree bitwise —
same contract the contiguous kernels already meet.  The lax fallbacks
are held to fp-reassociation tolerance, and an identity-layout
crosscheck pins the paged refs to the contiguous ones (same math, page
table == identity).
"""
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.cache_update.ops as cu_ops
import repro.kernels.decode_attention.ops as da_ops
import repro.kernels.prefill_attention.ops as pf_ops
from repro.kernels.cache_update.ref import paged_cache_update_ref
from repro.kernels.decode_attention.ref import (decode_attention_paged_ref,
                                                decode_attention_ref)
from repro.kernels.prefill_attention.ref import (prefill_attention_paged_ref,
                                                 prefill_attention_ref)


def key(i):
    return jax.random.PRNGKey(i)


def scrambled_table(seed, b, nb, num_pages):
    """A (B, NB) page table of distinct physical pages, never page 0,
    deliberately out of logical order."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, num_pages))[:b * nb]
    return jnp.asarray(perm.reshape(b, nb), jnp.int32)


def gather_logical(pool, pt):
    b, nb = pt.shape
    ps = pool.shape[1]
    return jnp.take(pool, pt, axis=0).reshape(b, nb * ps, *pool.shape[2:])


# ---------------------------------------------------------------------------
# paged cache_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,rest", [(1, (2, 8)), (8, (2, 8)), (8, (24,))])
def test_paged_cache_update_interpret_bitwise(t, rest):
    b, nb, ps = 3, 4, 4
    num_pages = b * nb + 1
    pool = jax.random.normal(key(0), (num_pages, ps, *rest), jnp.float32)
    new = jax.random.normal(key(1), (b, t, *rest), jnp.float32)
    pt = scrambled_table(2, b, nb, num_pages)
    starts = jnp.array([0, 5, nb * ps - t], jnp.int32)
    valids = jnp.array([t, max(t - 2, 0), t], jnp.int32)

    got = cu_ops.paged_cache_update(pool, new, pt, starts, valids,
                                    impl="pallas_interpret")
    want = paged_cache_update_ref(pool, new, pt, starts, valids)
    # page 0 is scratch: masked rows land there and its content is
    # undefined by contract — compare every real page bitwise.
    np.testing.assert_array_equal(np.asarray(got)[1:], np.asarray(want)[1:])


def test_paged_cache_update_matches_contiguous_semantics():
    """Through an identity layout, the paged scatter must equal writing
    new[b, :valids[b]] at starts[b] of a contiguous (B, C, F) cache."""
    b, nb, ps, t, f = 2, 3, 4, 4, 6
    num_pages = b * nb + 1
    pt = jnp.arange(1, num_pages, dtype=jnp.int32).reshape(b, nb)
    pool = jax.random.normal(key(3), (num_pages, ps, f), jnp.float32)
    new = jax.random.normal(key(4), (b, t, f), jnp.float32)
    starts = jnp.array([2, 7], jnp.int32)
    valids = jnp.array([4, 3], jnp.int32)

    got = gather_logical(
        paged_cache_update_ref(pool, new, pt, starts, valids), pt)
    want = np.array(gather_logical(pool, pt))
    for i in range(b):
        s, v = int(starts[i]), int(valids[i])
        want[i, s:s + v] = np.asarray(new)[i, :v]
    np.testing.assert_array_equal(np.asarray(got), want)


def test_paged_cache_update_masked_rows_leave_pages_untouched():
    b, nb, ps, f = 2, 2, 4, 8
    num_pages = b * nb + 1
    pool = jax.random.normal(key(5), (num_pages, ps, f), jnp.float32)
    new = jax.random.normal(key(6), (b, 4, f), jnp.float32)
    pt = scrambled_table(7, b, nb, num_pages)
    out = cu_ops.paged_cache_update(pool, new, pt,
                                    jnp.zeros(b, jnp.int32),
                                    jnp.zeros(b, jnp.int32),
                                    impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out)[1:], np.asarray(pool)[1:])


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------


def _decode_case(seed, b=3, nb=4, ps=16, kvh=2, g=4, hd=32, hdv=24,
                 alias=False):
    # Same dimension class as the contiguous bitwise gates
    # (test_decode_attention.py): hdq=32, hdv=24, C=64, block=16,
    # G in {1, 4, 8}.  That is the regime where the CPU
    # interpret-mode matmul lowering agrees bitwise with the einsum
    # oracle — at e.g. G=2 XLA picks a different contraction order
    # and even the *contiguous* kernel/ref pair splits by ~1 ulp.
    num_pages = b * nb + 1
    q = jax.random.normal(key(seed), (b, kvh, g, hd), jnp.float32)
    k = jax.random.normal(key(seed + 1), (num_pages, ps, kvh, hd),
                          jnp.float32)
    v = k if alias else jax.random.normal(
        key(seed + 2), (num_pages, ps, kvh, hdv), jnp.float32)
    pt = scrambled_table(seed + 3, b, nb, num_pages)
    lens = jnp.array([0, nb * ps // 2, nb * ps - 1][:b], jnp.int32)
    return q, k, v, pt, lens


@pytest.mark.parametrize("window", [None, 11])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_paged_decode_interpret_bitwise(window, softcap):
    q, k, v, pt, lens = _decode_case(10)
    kw = dict(window=window, softcap=softcap,
              scale=1.0 / math.sqrt(32))
    got = da_ops.decode_attention_paged_pallas(q, k, v, pt, lens,
                                               interpret=True, **kw)
    want = decode_attention_paged_ref(q, k, v, pt, lens, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_decode_vwidth_alias_interpret_bitwise():
    q, k, v, pt, lens = _decode_case(20, alias=True)
    kw = dict(scale=0.5, v_width=24)
    got = da_ops.decode_attention_paged_pallas(q, k, v, pt, lens,
                                               interpret=True, **kw)
    want = decode_attention_paged_ref(q, k, v, pt, lens, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("window", [None, 13])
def test_paged_decode_lax_matches_ref(window):
    q, k, v, pt, lens = _decode_case(30)
    kw = dict(window=window, softcap=50.0, scale=0.3)
    got = da_ops.decode_attention_paged_lax(q, k, v, pt, lens, **kw)
    want = decode_attention_paged_ref(q, k, v, pt, lens, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_paged_decode_ref_equals_contiguous_ref_on_identity_layout():
    b, nb, ps = 2, 4, 16
    num_pages = b * nb + 1
    q, k, v, _, lens = _decode_case(40, b=b, nb=nb, ps=ps)
    pt = jnp.arange(1, num_pages, dtype=jnp.int32).reshape(b, nb)
    k_log, v_log = gather_logical(k, pt), gather_logical(v, pt)
    got = decode_attention_paged_ref(q, k, v, pt, lens, scale=0.4)
    want = decode_attention_ref(q, k_log, v_log, lens, ring=False,
                                scale=0.4, block_k=ps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_decode_wrapper_layout():
    b, nb, ps, kvh, g, hd = 2, 3, 4, 2, 3, 8
    num_pages = b * nb + 1
    h = kvh * g
    q = jax.random.normal(key(50), (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(key(51), (num_pages, ps, kvh, hd), jnp.float32)
    v = jax.random.normal(key(52), (num_pages, ps, kvh, hd), jnp.float32)
    pt = scrambled_table(53, b, nb, num_pages)
    lens = jnp.array([3, 9], jnp.int32)
    out = da_ops.decode_attention_paged(q, k, v, pt, lens, impl="lax")
    assert out.shape == (b, 1, h, hd)
    want = decode_attention_paged_ref(
        q.reshape(b, kvh, g, hd), k, v, pt, lens)
    np.testing.assert_allclose(np.asarray(out).reshape(b, kvh, g, hd),
                               np.asarray(want), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# paged prefill attention
# ---------------------------------------------------------------------------


def _prefill_case(seed, b=3, nb=4, ps=16, t=16, kvh=2, g=4, hd=32,
                  hdv=24, alias=False):
    # Same dimension class as the contiguous bitwise gates
    # (test_prefill_attention.py) — see the _decode_case note on G.
    num_pages = b * nb + 1
    q = jax.random.normal(key(seed), (b, kvh, t, g, hd), jnp.float32)
    kx = jax.random.normal(key(seed + 1), (b, t, kvh, hd), jnp.float32)
    vx = kx if alias else jax.random.normal(key(seed + 2),
                                            (b, t, kvh, hdv), jnp.float32)
    kc = jax.random.normal(key(seed + 3), (num_pages, ps, kvh, hd),
                           jnp.float32)
    vc = kc if alias else jax.random.normal(
        key(seed + 4), (num_pages, ps, kvh, hdv), jnp.float32)
    pt = scrambled_table(seed + 5, b, nb, num_pages)
    offs = jnp.array([0, 5, nb * ps - t][:b], jnp.int32)
    return q, kx, vx, kc, vc, pt, offs


@pytest.mark.parametrize("window", [None, 11])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_paged_prefill_interpret_bitwise(window, softcap):
    q, kx, vx, kc, vc, pt, offs = _prefill_case(60)
    kw = dict(window=window, softcap=softcap,
              scale=1.0 / math.sqrt(32))
    got = pf_ops.prefill_attention_paged_pallas(q, kx, vx, kc, vc, pt, offs,
                                                interpret=True, **kw)
    want = prefill_attention_paged_ref(q, kx, vx, kc, vc, pt, offs, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_prefill_vwidth_alias_interpret_bitwise():
    q, kx, vx, kc, vc, pt, offs = _prefill_case(70, alias=True)
    kw = dict(scale=0.5, v_width=24)
    got = pf_ops.prefill_attention_paged_pallas(q, kx, vx, kc, vc, pt, offs,
                                                interpret=True, **kw)
    want = prefill_attention_paged_ref(q, kx, vx, kc, vc, pt, offs, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("window", [None, 13])
def test_paged_prefill_lax_matches_ref(window):
    q, kx, vx, kc, vc, pt, offs = _prefill_case(80)
    kw = dict(window=window, softcap=25.0, scale=0.3)
    got = pf_ops.prefill_attention_paged_lax(q, kx, vx, kc, vc, pt, offs,
                                             **kw)
    want = prefill_attention_paged_ref(q, kx, vx, kc, vc, pt, offs, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


def test_paged_prefill_ref_equals_contiguous_ref_on_identity_layout():
    b, nb, ps, t = 2, 4, 16, 16
    num_pages = b * nb + 1
    q, kx, vx, kc, vc, _, offs = _prefill_case(90, b=b, nb=nb, ps=ps, t=t)
    pt = jnp.arange(1, num_pages, dtype=jnp.int32).reshape(b, nb)
    got = prefill_attention_paged_ref(q, kx, vx, kc, vc, pt, offs, scale=0.4)
    want = prefill_attention_ref(q, kx, vx, gather_logical(kc, pt),
                                 gather_logical(vc, pt), offs, ring=False,
                                 scale=0.4, block_k=ps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_prefill_wrapper_layout():
    b, nb, ps, t, kvh, g, hd = 2, 3, 4, 4, 2, 2, 8
    num_pages = b * nb + 1
    h = kvh * g
    q = jax.random.normal(key(95), (b, t, h, hd), jnp.float32)
    kx = jax.random.normal(key(96), (b, t, kvh, hd), jnp.float32)
    vx = jax.random.normal(key(97), (b, t, kvh, hd), jnp.float32)
    kc = jax.random.normal(key(98), (num_pages, ps, kvh, hd), jnp.float32)
    vc = jax.random.normal(key(99), (num_pages, ps, kvh, hd), jnp.float32)
    pt = scrambled_table(100, b, nb, num_pages)
    offs = jnp.array([0, 7], jnp.int32)
    out = pf_ops.prefill_attention_paged(q, kx, vx, kc, vc, pt, offs,
                                         impl="lax")
    assert out.shape == (b, t, h, hd)
    want = prefill_attention_paged_ref(
        q.reshape(b, t, kvh, g, hd).transpose(0, 2, 1, 3, 4),
        kx, vx, kc, vc, pt, offs)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want).transpose(0, 2, 1, 3, 4)
        .reshape(b, t, h, hd), rtol=2e-6, atol=2e-6)
