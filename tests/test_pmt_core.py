"""Unit + property tests for the PMT core (the paper's contribution)."""
import math
import os
import threading
import time

import pytest
# Shared strategies package: real hypothesis when installed, a
# deterministic-grid fallback otherwise (see tests/strategies).
from strategies import HAS_HYPOTHESIS, given, settings, st

import repro.core as pmt
from repro.core.sensor import Sample, Sensor, SensorError
from repro.core.state import State


# ---------------------------------------------------------------------------
# State derivations: joules / watts / seconds (paper Listing 1 semantics)
# ---------------------------------------------------------------------------

finite = st.floats(min_value=0.0, max_value=1e12, allow_nan=False,
                   allow_infinity=False)


@given(t0=st.floats(min_value=0.0, max_value=1e6),
       dt=st.floats(min_value=1e-6, max_value=1e6),
       j0=finite, dj=st.floats(min_value=0.0, max_value=1e9))
def test_state_identities(t0, dt, j0, dj):
    a = State(timestamp_s=t0, joules=j0)
    b = State(timestamp_s=t0 + dt, joules=j0 + dj)
    s = pmt.seconds(a, b)
    j = pmt.joules(a, b)
    w = pmt.watts(a, b)
    # abs tolerance covers float cancellation in (t0 + dt) - t0
    assert s == pytest.approx(dt, rel=1e-6, abs=1e-5)
    assert j == pytest.approx(dj, rel=1e-6, abs=1e-3)
    # J = W * s — the fundamental identity the API exposes.
    assert j == pytest.approx(w * s, rel=1e-6, abs=1e-6)


def test_zero_interval_watts_is_zero():
    a = State(timestamp_s=5.0, joules=10.0)
    assert pmt.watts(a, a) == 0.0


def test_negative_joules_rejected():
    with pytest.raises(ValueError):
        State(timestamp_s=0.0, joules=-1.0)


def test_rail_joules():
    a = State(0.0, 0.0, rails={"pkg": 1.0, "dram": 0.5})
    b = State(1.0, 2.0, rails={"pkg": 2.5, "dram": 0.75})
    assert pmt.rail_joules(a, b, "pkg") == pytest.approx(1.5)
    with pytest.raises(KeyError):
        pmt.rail_joules(a, b, "gpu")


# ---------------------------------------------------------------------------
# Sensor base class: power integration for power-only backends
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_power_only_backend_trapezoidal_integration():
    clk = FakeClock()
    s = pmt.create("dummy", watts=100.0, clock=clk)
    a = s.read()
    clk.advance(2.0)
    b = s.read()
    # constant 100 W over 2 s -> 200 J
    assert pmt.joules(a, b) == pytest.approx(200.0)
    assert pmt.watts(a, b) == pytest.approx(100.0)


def test_waveform_backend_trapezoid_matches_analytic():
    clk = FakeClock()
    # ramp 0 -> 100 W over 1 s: trapezoid with samples at 0 and 1 gives 50 J
    s = pmt.create("dummy", watts_fn=lambda t: 100.0 * t, clock=clk)
    a = s.read()
    clk.advance(1.0)
    b = s.read()
    assert pmt.joules(a, b) == pytest.approx(50.0)


def test_sensor_requires_some_reading():
    class Bad(Sensor):
        name = "bad"

        def _sample(self):
            return Sample()

    with pytest.raises(SensorError):
        Bad().read()


def test_monotone_joules_under_many_reads():
    clk = FakeClock()
    s = pmt.create("dummy", watts=7.0, clock=clk)
    last = s.read()
    for _ in range(50):
        clk.advance(0.01)
        cur = s.read()
        assert cur.joules >= last.joules
        last = cur


# ---------------------------------------------------------------------------
# Registry (paper: extensible back ends)
# ---------------------------------------------------------------------------

def test_registry_contains_paper_backends():
    names = pmt.backend_names()
    for expected in ["rapl", "sysfs", "nvml", "cpuutil", "tpu", "dummy"]:
        assert expected in names


def test_registry_unknown_backend():
    with pytest.raises(KeyError):
        pmt.create("powersensor99")


def test_registry_extension_point():
    class MySensor(Sensor):
        name = "custom"

        def _sample(self):
            return Sample(watts=1.0)

    pmt.register_backend("custom", MySensor)
    try:
        s = pmt.create("custom")
        assert isinstance(s, MySensor)
    finally:
        # keep global registry clean for other tests
        from repro.core import registry
        registry._REGISTRY.pop("custom", None)


# ---------------------------------------------------------------------------
# RAPL backend against a fixture powercap tree (incl. wraparound)
# ---------------------------------------------------------------------------

def _write(path, content):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(str(content))


def make_rapl_tree(root, packages=2, energy_uj=1000000, max_range=10000000):
    for i in range(packages):
        zone = os.path.join(root, f"intel-rapl:{i}")
        _write(os.path.join(zone, "name"), f"package-{i}")
        _write(os.path.join(zone, "energy_uj"), energy_uj)
        _write(os.path.join(zone, "max_energy_range_uj"), max_range)
        # one subzone (must NOT be double counted in the total)
        sub = os.path.join(root, f"intel-rapl:{i}:0")
        _write(os.path.join(sub, "name"), "core")
        _write(os.path.join(sub, "energy_uj"), energy_uj // 2)
        _write(os.path.join(sub, "max_energy_range_uj"), max_range)


def test_rapl_fixture_tree(tmp_path):
    root = str(tmp_path / "powercap")
    make_rapl_tree(root, packages=2, energy_uj=1_000_000)
    clk = FakeClock()
    s = pmt.create("rapl", root=root, clock=clk)
    assert s.kind == "measured"
    a = s.read()
    # both packages advance by 0.5 J (500000 uJ); subzones by 0.25 J
    for i in range(2):
        _write(os.path.join(root, f"intel-rapl:{i}", "energy_uj"), 1_500_000)
        _write(os.path.join(root, f"intel-rapl:{i}:0", "energy_uj"), 750_000)
    clk.advance(1.0)
    b = s.read()
    assert pmt.joules(a, b) == pytest.approx(1.0)  # 2 packages x 0.5 J
    assert pmt.watts(a, b) == pytest.approx(1.0)
    assert pmt.rail_joules(a, b, "intel-rapl:0:0:core") == pytest.approx(0.25)


def test_rapl_wraparound(tmp_path):
    root = str(tmp_path / "powercap")
    make_rapl_tree(root, packages=1, energy_uj=9_900_000, max_range=10_000_000)
    clk = FakeClock()
    s = pmt.create("rapl", root=root, clock=clk)
    a = s.read()
    # counter wraps: 9.9e6 -> 0.1e6 over max_range 1e7 => +0.2 J consumed
    _write(os.path.join(root, "intel-rapl:0", "energy_uj"), 100_000)
    _write(os.path.join(root, "intel-rapl:0:0", "energy_uj"), 100_000)
    clk.advance(1.0)
    b = s.read()
    assert pmt.joules(a, b) == pytest.approx(0.2)


def test_rapl_unavailable_without_tree(tmp_path):
    with pytest.raises(SensorError):
        pmt.create("rapl", root=str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# sysfs backend against a fixture hwmon tree
# ---------------------------------------------------------------------------

def test_sysfs_power_files(tmp_path):
    p1 = str(tmp_path / "hwmon0" / "power1_input")
    p2 = str(tmp_path / "hwmon1" / "power1_input")
    _write(p1, 25_000_000)  # 25 W in uW
    _write(p2, 10_000_000)  # 10 W
    clk = FakeClock()
    s = pmt.create("sysfs", files=[p1, p2], clock=clk)
    a = s.read()
    clk.advance(2.0)
    b = s.read()
    assert pmt.joules(a, b) == pytest.approx(70.0)  # 35 W x 2 s
    assert b.watts == pytest.approx(35.0)


def test_sysfs_energy_files(tmp_path):
    e = str(tmp_path / "hwmon0" / "energy1_input")
    _write(e, 1_000_000)  # 1 J in uJ
    clk = FakeClock()
    s = pmt.create("sysfs", files=[e], clock=clk)
    a = s.read()
    _write(e, 4_000_000)
    clk.advance(1.0)
    b = s.read()
    assert pmt.joules(a, b) == pytest.approx(3.0)


def test_sysfs_rejects_unknown_file(tmp_path):
    f = str(tmp_path / "hwmon0" / "temp1_input")
    _write(f, 42)
    with pytest.raises(SensorError):
        pmt.create("sysfs", files=[f])


# ---------------------------------------------------------------------------
# cpuutil backend against fixture /proc/stat
# ---------------------------------------------------------------------------

def make_proc(tmp_path, busy, idle):
    # user nice system idle iowait irq softirq steal
    _write(str(tmp_path / "proc" / "stat"),
           f"cpu {busy} 0 0 {idle} 0 0 0 0 0 0\n")
    return str(tmp_path / "proc")


def test_cpuutil_utilization_model(tmp_path):
    procfs = make_proc(tmp_path, busy=100, idle=900)
    clk = FakeClock()
    s = pmt.create("cpuutil", tdp_w=110.0, idle_w=10.0, procfs=procfs,
                   clock=clk)
    s.read()
    # now 50% utilization over the delta: +100 busy, +100 idle
    make_proc(tmp_path, busy=200, idle=1000)
    clk.advance(1.0)
    b = s.read()
    # P = 10 + (110-10)*0.5 = 60 W
    assert b.watts == pytest.approx(60.0)
    assert s.kind == "hybrid"


def test_cpuutil_clamps_utilization(tmp_path):
    procfs = make_proc(tmp_path, busy=100, idle=900)
    s = pmt.create("cpuutil", procfs=procfs, clock=FakeClock())
    s.read()
    make_proc(tmp_path, busy=90, idle=900)  # counter went backwards
    assert 0.0 <= s.utilization() <= 1.0


# ---------------------------------------------------------------------------
# TPU cost-model backend (the TPU-native adaptation)
# ---------------------------------------------------------------------------

def test_tpu_sensor_idle_floor():
    clk = FakeClock()
    s = pmt.create("tpu", chips=2, clock=clk)
    a = s.read()
    clk.advance(10.0)
    b = s.read()
    # idle 60 W x 2 chips x 10 s
    assert pmt.joules(a, b) == pytest.approx(1200.0)
    assert s.kind == "modeled"


def test_tpu_sensor_accounts_dynamic_energy():
    clk = FakeClock()
    s = pmt.create("tpu", chips=1, clock=clk)
    a = s.read()
    dyn = s.account(flops=1e12, hbm_bytes=0.0, ici_bytes=0.0, seconds=1.0)
    # 1e12 FLOP x 0.55 pJ = 0.55 J of dynamic energy
    assert dyn == pytest.approx(0.55)
    clk.advance(1.0)
    b = s.read()
    assert pmt.joules(a, b) == pytest.approx(60.0 + 0.55)


def test_tpu_sensor_power_cap():
    s = pmt.create("tpu", chips=1, clock=FakeClock())
    # absurd FLOPs in 1 s must be capped at (peak - idle) x 1 s
    dyn = s.account(flops=1e20, hbm_bytes=0, ici_bytes=0, seconds=1.0)
    assert dyn == pytest.approx(200.0 - 60.0)


@given(flops=st.floats(0, 1e18), hbm=st.floats(0, 1e15),
       ici=st.floats(0, 1e15), secs=st.floats(1e-3, 1e3))
@settings(max_examples=50, deadline=None)
def test_energy_model_properties(flops, hbm, ici, secs):
    m = pmt.EnergyModel()
    e = m.step_joules(flops, hbm, ici, secs)
    # never below the idle floor, never above the board envelope
    assert e >= m.static_joules(secs) - 1e-9
    assert e <= m.hw.peak_w * secs + 1e-6
    # monotone in each activity term (pre-cap region check via dynamic)
    assert m.dynamic_joules(flops + 1e9, hbm, ici) >= m.dynamic_joules(
        flops, hbm, ici)


# ---------------------------------------------------------------------------
# Dump mode (paper mode 1)
# ---------------------------------------------------------------------------

def test_dump_mode_roundtrip(tmp_path):
    path = str(tmp_path / "trace.pmt")
    s = pmt.create("dummy", watts=20.0)
    s.start_dump_thread(path, period_s=0.005)
    time.sleep(0.06)
    s.stop_dump_thread()
    hdr, recs = pmt.read_dump(path)
    assert hdr.sensor == "dummy" and hdr.kind == "modeled"
    assert len(recs) >= 3
    assert pmt.average_watts(recs) == pytest.approx(20.0, rel=0.05)
    # timestamps strictly non-decreasing, joules non-decreasing
    for r0, r1 in zip(recs, recs[1:]):
        assert r1.t_rel_s >= r0.t_rel_s
        assert r1.joules >= r0.joules


def test_dump_thread_double_start_rejected(tmp_path):
    s = pmt.create("dummy")
    s.start_dump_thread(str(tmp_path / "a.pmt"))
    try:
        with pytest.raises(SensorError):
            s.start_dump_thread(str(tmp_path / "b.pmt"))
    finally:
        s.stop_dump_thread()


def test_dump_reader_rejects_garbage(tmp_path):
    p = tmp_path / "bad.pmt"
    p.write_text("hello world\n1 2 3\n")
    with pytest.raises(ValueError):
        pmt.read_dump(str(p))


def test_period_clamped_to_native(tmp_path):
    from repro.core.sampler import clamp_period
    s = pmt.create("dummy")  # native 1 ms
    assert clamp_period(s, None) == s.native_period_s
    assert clamp_period(s, 1e-9) == s.native_period_s
    assert clamp_period(s, 0.5) == 0.5


# ---------------------------------------------------------------------------
# Decorators (paper Listing 2) + stacking
# ---------------------------------------------------------------------------

def test_measure_decorator_returns_measurements():
    @pmt.measure("dummy")
    def app():
        time.sleep(0.01)
        return "payload"

    measures = app()
    assert isinstance(measures, pmt.Measurements)
    assert measures.result == "payload"
    assert len(measures) == 1
    m = measures[0]
    assert m.sensor == "dummy"
    assert m.seconds >= 0.01
    assert m.joules == pytest.approx(m.watts * m.seconds, rel=1e-6)
    assert "J" in str(m) and "W" in str(m)


def test_stacked_decorators_merge():
    @pmt.measure("tpu")
    @pmt.measure("dummy")
    def app():
        time.sleep(0.005)
        return 7

    measures = app()
    assert {m.sensor for m in measures} == {"tpu", "dummy"}
    assert measures.result == 7
    assert measures.by_sensor("tpu").kind == "modeled"


def test_multi_backend_single_decorator():
    @pmt.measure("dummy", "tpu")
    def app():
        return None

    measures = app()
    assert {m.sensor for m in measures} == {"dummy", "tpu"}
    assert measures.total_joules() >= 0.0


def test_measure_requires_backend():
    with pytest.raises(ValueError):
        pmt.measure()


def test_dump_decorator(tmp_path):
    path = str(tmp_path / "dec.pmt")

    @pmt.dump("dummy", filename=path, period_s=0.005)
    def app():
        time.sleep(0.03)
        return 5

    assert app() == 5  # return value passes through in dump mode
    hdr, recs = pmt.read_dump(path)
    assert len(recs) >= 2


def test_region_context_manager():
    with pmt.Region("dummy", label="roi") as r:
        time.sleep(0.002)
    m = r.measurement
    assert m is not None and m.label == "roi" and m.seconds > 0


def test_decorator_accepts_sensor_instance():
    sensor = pmt.create("dummy", watts=5.0)

    @pmt.measure(sensor)
    def app():
        return 1

    m = app()[0]
    assert m.sensor == "dummy"


# ---------------------------------------------------------------------------
# Metrics (paper §III)
# ---------------------------------------------------------------------------

@given(j=st.floats(1e-9, 1e9), s=st.floats(1e-9, 1e6))
def test_edp_properties(j, s):
    assert pmt.edp(j, s) == pytest.approx(j * s)
    assert pmt.ed2p(j, s) == pytest.approx(j * s * s)
    assert pmt.edp(2 * j, s) > pmt.edp(j, s)


@given(flops=st.floats(1.0, 1e18), j=st.floats(1e-6, 1e9))
def test_gflops_per_watt_identity(flops, j):
    # GFLOP/s/W == flops / joules / 1e9 (seconds cancel)
    g = pmt.gflops_per_watt(flops, j)
    assert g == pytest.approx(flops / j / 1e9)


def test_efficiency_report_csv():
    r = pmt.EfficiencyReport(joules=10.0, seconds=2.0, flops=1e12,
                             tokens=1000)
    assert r.watts == pytest.approx(5.0)
    assert r.gflops_per_watt == pytest.approx(100.0)
    assert r.joules_per_token == pytest.approx(0.01)
    row = r.as_csv_row()
    assert len(row.split(",")) == len(r.CSV_HEADER.split(","))


# ---------------------------------------------------------------------------
# PowerMonitor + straggler detection (framework integration)
# ---------------------------------------------------------------------------

def test_power_monitor_step_attribution(tmp_path):
    log = str(tmp_path / "energy.csv")
    clk = FakeClock()
    sensor = pmt.create("dummy", watts=100.0, clock=clk)
    mon = pmt.PowerMonitor([sensor], log_path=log)
    for i in range(3):
        with mon.measure_step(step=i, flops=1e9, tokens=10) as box:
            clk.advance(1.0)
        assert box.records[0].joules == pytest.approx(100.0)
    assert mon.cumulative_joules == pytest.approx(300.0)
    mon.close()
    lines = open(log).read().strip().splitlines()
    assert lines[0].startswith("step,sensor")
    assert len(lines) == 4


def test_power_monitor_resume_from_checkpoint_energy():
    mon = pmt.PowerMonitor(["dummy"], initial_joules=1234.5)
    assert mon.cumulative_joules == pytest.approx(1234.5)
    sd = mon.state_dict()
    assert sd["cumulative_joules"] == pytest.approx(1234.5)


def test_straggler_detection_requires_both_signals():
    # host 5 is slow AND power-anomalous -> straggler
    v = pmt.detect_stragglers([100, 101, 99, 100, 100, 40],
                              [1.0, 1.01, 0.99, 1.0, 1.0, 3.5])
    assert [x.is_straggler for x in v] == [False] * 5 + [True]
    # slow but power-normal -> data skew, not a straggler
    v2 = pmt.detect_stragglers([100, 101, 99, 100, 100, 100],
                               [1.0, 1.01, 0.99, 1.0, 1.0, 3.5])
    assert not v2[5].is_straggler


def test_straggler_empty_and_mismatch():
    assert pmt.detect_stragglers([], []) == []
    with pytest.raises(ValueError):
        pmt.detect_stragglers([1.0], [1.0, 2.0])


def test_monitor_thread_safety():
    mon = pmt.PowerMonitor(["dummy"])
    errs = []

    def work(i):
        try:
            with mon.measure_step(step=i):
                time.sleep(0.001)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(mon.records()) == 8
