"""Tests for the unified pmt.Session API: shared sampling service,
nested non-blocking regions, pool refcounting, exporters, and the
backward-compat shims that ride on the default session."""
import threading
import time

import pytest

import repro.core as pmt
from repro.core.sensor import Sample, Sensor
from repro.core.session import SensorPool, Session


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Regions: resolution correctness, nesting, concurrency
# ---------------------------------------------------------------------------

def test_region_resolves_exact_joules_with_virtual_clock():
    clk = FakeClock()
    sensor = pmt.create("dummy", watts=100.0, clock=clk)
    with Session([sensor], pool=SensorPool()) as sess:
        with sess.region("roi") as r:
            clk.advance(2.0)
        m = r.measurements[0]
    # constant 100 W over 2 s, resolved off the ring buffer
    assert m.joules == pytest.approx(200.0)
    assert m.watts == pytest.approx(100.0)
    assert m.seconds == pytest.approx(2.0)
    assert m.label == "roi"


def test_region_entry_exit_touch_no_sensor():
    """The non-blocking contract: open/close must not call _sample()."""

    class CountingSensor(Sensor):
        name = "counting"
        kind = "modeled"
        native_period_s = 3600.0  # background thread effectively idle

        def __init__(self, **kw):
            super().__init__(**kw)
            self.samples = 0

        def _sample(self):
            self.samples += 1
            return Sample(watts=1.0)

    sensor = CountingSensor()
    with Session([sensor], pool=SensorPool()) as sess:
        time.sleep(0.05)                 # let the thread's initial tick land
        before = sensor.samples          # pool seed + thread start samples
        for _ in range(50):
            with sess.region("hot"):
                pass
        assert sensor.samples == before  # zero reads on the hot path
        sess.flush()                     # resolution may sample (off-path)
    assert sensor.samples > before


def test_nested_regions_paths_and_depth():
    with Session(["dummy"], pool=SensorPool()) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        with sess.region("outer"):
            with sess.region("mid"):
                with sess.region("leaf"):
                    pass
        sess.flush()
        paths = sorted((r.path, r.depth) for r in mem.records)
    assert paths == [("outer", 0), ("outer/mid", 1), ("outer/mid/leaf", 2)]


def test_concurrent_regions_from_many_threads():
    clk_errors = []
    with Session(["dummy"], pool=SensorPool()) as sess:
        results = {}

        def work(i):
            try:
                with sess.region(f"t{i}") as r:
                    time.sleep(0.002)
                results[i] = r.measurements[0]
            except Exception as e:  # pragma: no cover
                clk_errors.append(e)

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not clk_errors
    assert len(results) == 16
    for i, m in results.items():
        assert m.label == f"t{i}"
        assert m.seconds > 0 and m.joules >= 0.0


def test_multi_sensor_aggregation():
    with Session(["dummy", "tpu"], pool=SensorPool()) as sess:
        with sess.region("both") as r:
            time.sleep(0.005)
        ms = r.measurements
    assert {m.sensor for m in ms} == {"dummy", "tpu"}
    assert ms.total_joules() >= 0.0
    assert ms.by_sensor("tpu").kind == "modeled"


def test_region_on_empty_session_raises():
    with Session(pool=SensorPool()) as sess:
        with pytest.raises(pmt.SensorError):
            with sess.region("nope"):
                pass


def test_region_resolution_before_exit_raises():
    with Session(["dummy"], pool=SensorPool()) as sess:
        with sess.region("open") as r:
            with pytest.raises(pmt.SensorError):
                r.measurements


# ---------------------------------------------------------------------------
# SensorPool refcounting
# ---------------------------------------------------------------------------

def test_pool_shares_one_sampler_and_stops_on_last_detach():
    pool = SensorPool()
    a = pool.acquire("dummy")
    b = pool.acquire("dummy")
    assert a.sensor is b.sensor
    sampler = a.sampler
    assert sampler is b.sampler and sampler.is_alive()
    assert pool.live_sampler_count() == 1

    a.release()
    assert sampler.is_alive()            # b still holds it
    assert pool.live_sampler_count() == 1
    b.release()
    assert not sampler.is_alive()        # last consumer detached
    assert pool.live_sampler_count() == 0


def test_pool_release_is_idempotent():
    pool = SensorPool()
    a = pool.acquire("dummy")
    b = pool.acquire("dummy")
    a.release()
    a.release()                          # double release must not steal b's ref
    assert b.sampler is not None and b.sampler.is_alive()
    b.release()
    assert pool.live_sampler_count() == 0


def test_pool_distinguishes_backend_kwargs():
    pool = SensorPool()
    a = pool.acquire("dummy", watts=5.0)
    b = pool.acquire("dummy", watts=9.0)
    try:
        assert a.sensor is not b.sensor
        assert pool.live_sampler_count() == 2
    finally:
        a.release()
        b.release()


def test_sessions_share_pool_sampler():
    pool = SensorPool()
    with Session(["dummy"], pool=pool) as s1:
        with Session(["dummy"], pool=pool) as s2:
            assert s1.sensors[0] is s2.sensors[0]
            assert pool.live_sampler_count() == 1
        assert pool.live_sampler_count() == 1    # s1 still attached
    assert pool.live_sampler_count() == 0


def test_failed_session_constructor_releases_acquired_leases():
    pool = SensorPool()
    with pytest.raises(KeyError):
        Session(["dummy", "not-a-backend"], pool=pool)
    # the dummy sampler acquired before the failure must not leak
    assert pool.live_sampler_count() == 0


def test_decorator_lease_released_when_wrapper_collected():
    import gc

    from repro.core.session import default_pool

    sensor = pmt.create("dummy", watts=3.0)
    wrapped = pmt.measure(sensor)(lambda: None)
    key = ("instance", id(sensor))
    assert key in default_pool()._entries
    del wrapped
    gc.collect()
    assert key not in default_pool()._entries


def test_monitor_on_shared_session_uses_same_sampler():
    pool = SensorPool()
    with Session(["dummy"], pool=pool) as sess:
        mon = pmt.PowerMonitor(session=sess)
        with mon.measure_step(0, tokens=4) as box:
            time.sleep(0.002)
        assert box.records and box.records[0].sensor == "dummy"
        assert pool.live_sampler_count() == 1    # no second sampler
        mon.close()                              # does not close shared session
        with sess.region("still-works"):
            pass
    assert pool.live_sampler_count() == 0


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def test_jsonl_exporter_roundtrip(tmp_path):
    path = str(tmp_path / "regions.jsonl")
    clk = FakeClock()
    sensor = pmt.create("dummy", watts=50.0, clock=clk)
    with Session([sensor], pool=SensorPool(),
                 exporters=[pmt.JsonlExporter(path)]) as sess:
        with sess.region("a", tokens=32):
            clk.advance(1.0)
        with sess.region("b", flops=1e9):
            clk.advance(0.5)
        sess.flush()
    recs = pmt.read_jsonl(path)
    assert [r.path for r in recs] == ["a", "b"]
    assert recs[0].joules == pytest.approx(50.0)
    assert recs[0].tokens == 32 and recs[0].flops is None
    assert recs[1].joules == pytest.approx(25.0)
    assert recs[1].flops == pytest.approx(1e9) and recs[1].tokens is None
    for r in recs:
        assert isinstance(r, pmt.RegionRecord)
        assert r.sensor == "dummy" and r.kind == "modeled"


def test_csv_exporter_writes_header_and_rows(tmp_path):
    path = str(tmp_path / "regions.csv")
    with Session(["dummy"], pool=SensorPool(),
                 exporters=[pmt.CsvExporter(path)]) as sess:
        with sess.region("x"):
            time.sleep(0.002)
        sess.flush()
    lines = open(path).read().strip().splitlines()
    assert lines[0].startswith("path,label,depth,sensor")
    assert len(lines) == 2 and lines[1].startswith("x,x,0,dummy")


def test_csv_exporter_escapes_commas_in_labels(tmp_path):
    import csv as csv_mod

    path = str(tmp_path / "commas.csv")
    with Session(["dummy"], pool=SensorPool(),
                 exporters=[pmt.CsvExporter(path)]) as sess:
        with sess.region("load, transform"):
            pass
        sess.flush()
    with open(path, newline="") as f:
        rows = list(csv_mod.reader(f))
    assert len(rows) == 2
    assert len(rows[1]) == len(rows[0])          # columns stay aligned
    assert rows[1][0] == "load, transform"


def test_memory_exporter_subscriber_stream():
    seen = []
    mem = pmt.MemoryExporter()
    unsubscribe = mem.subscribe(seen.append)
    with Session(["dummy"], pool=SensorPool(), exporters=[mem]) as sess:
        with sess.region("one") as r:
            pass
        r.measurements          # resolution triggers emission
        assert [x.path for x in seen] == ["one"]
        unsubscribe()
        with sess.region("two"):
            pass
        sess.flush()
    assert [x.path for x in seen] == ["one"]     # unsubscribed before "two"
    assert [x.path for x in mem.records] == ["one", "two"]
    assert mem.total_joules() >= 0.0


def test_records_emitted_exactly_once():
    mem = pmt.MemoryExporter()
    with Session(["dummy"], pool=SensorPool(), exporters=[mem]) as sess:
        with sess.region("once") as r:
            pass
        r.measurements
        r.measurements          # cached — must not re-emit
        sess.flush()            # already resolved — must not re-emit
    assert len(mem.records) == 1


# ---------------------------------------------------------------------------
# Backward-compat shims
# ---------------------------------------------------------------------------

def test_measure_shim_still_returns_measurements():
    @pmt.measure("dummy")
    def app():
        time.sleep(0.002)
        return "ok"

    out = app()
    assert isinstance(out, pmt.Measurements)
    assert out.result == "ok"
    assert out[0].sensor == "dummy"
    assert out[0].joules == pytest.approx(out[0].watts * out[0].seconds,
                                          rel=1e-6)


def test_measure_shim_pools_sensors_across_decorators():
    @pmt.measure("dummy")
    def f():
        return 1

    @pmt.measure("dummy")
    def g():
        return 2

    # The redesign's whole point: no private per-decorator sensors.
    assert f.__pmt_sensors__[0] is g.__pmt_sensors__[0]
    assert (f() .result, g().result) == (1, 2)


def test_region_shim_resolves_only_its_backend():
    with pmt.Region("dummy", label="roi") as r:
        time.sleep(0.002)
    m = r.measurement
    assert m is not None and m.sensor == "dummy" and m.label == "roi"
    assert m.seconds > 0


def test_module_level_region_rides_default_session():
    with pmt.region("quick", backends=["dummy"]) as r:
        time.sleep(0.002)
    m = r.measurement
    assert m.sensor == "dummy" and m.seconds > 0
    # backends stick to the default session once attached
    with pmt.region("again") as r2:
        pass
    assert r2.measurements[0].sensor == "dummy"


def test_dump_decorator_rejects_concurrent_runs(tmp_path):
    path = str(tmp_path / "dump.pmt")
    release = threading.Event()
    errs = []

    @pmt.dump("dummy", filename=path, period_s=0.005)
    def slow():
        release.wait(timeout=5.0)

    t = threading.Thread(target=slow)
    t.start()
    time.sleep(0.02)          # first dump is live
    try:
        with pytest.raises(pmt.SensorError):
            slow()            # second concurrent run must be refused
    finally:
        release.set()
        t.join()
    # sequential re-run is fine once the first finished
    release.set()
    slow()
    hdr, recs = pmt.read_dump(path)
    assert len(recs) >= 2


def test_step_box_records_are_instance_scoped():
    from repro.core.monitor import _StepBox

    a, b = _StepBox(), _StepBox()
    a.records.append("x")
    assert b.records == []               # the old class-attribute footgun


def test_available_backends_survive_broken_is_available():
    class Broken(Sensor):
        name = "broken"

        @classmethod
        def is_available(cls):
            raise RuntimeError("probe exploded")

        def _sample(self):
            return Sample(watts=1.0)

    pmt.register_backend("broken", Broken)
    try:
        names = pmt.available_backend_names()
        assert "broken" not in names
        assert "dummy" in names          # enumeration not taken down
    finally:
        from repro.core import registry
        registry._REGISTRY.pop("broken", None)
