"""Quantized-KV A/B — bf16 cache vs int8 / fp8_e4m3 codes + in-register dequant.

Decode is memory-bound: the flash-decode path already skips the *dead*
cache bytes past each row's ``cur_len``; quantization shrinks the *live*
ones.  A quantized cache stores 1-byte codes plus one float32 absmax
scale per (token, kv-head) row — ~0.53x the bf16 bytes at head_dim 64 —
and the attention kernels dequantize blocks in-register inside the
online-softmax loop, so the HBM traffic per step drops by the same
ratio.  The win is bandwidth, the cost is bounded logit drift; this
bench commits both numbers.

The A/B drives the decode-attention layer (the serve hot path this
change targets) with one new token per row against a live cache at
three fills — an eighth, half, three-quarters — exactly the
BENCH_decode methodology: identical inputs per side, per-row ``cur_len``
vectors advancing each step, each (impl, fill) sweep fenced inside a
``pmt.Session`` region on the dummy backend so J/token reproduces in
CI (joules track wall-clock deterministically; real hardware swaps the
backend list only).

Accuracy rides in the same artifact: serve-path decode logits on the
reduced smollm config, quantized cache vs bf16, reported as max
absolute drift relative to the max |logit| and gated per mode (int8
<= 10%, fp8_e4m3 <= 20% — doubled headroom over measured drift; see
tests/test_quant_serve.py for the per-arch gates).

Pass criteria (written into BENCH_quant.json, validated by CI):
int8 >= 1.2x tokens/s AND <= 0.85x J/token vs the bf16 cache at every
measured fill >= half, and every mode's logit drift under its bound.

Usage: PYTHONPATH=src python benchmarks/bench_quant.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.kernels import quant
from repro.kernels.decode_attention import ops as da_ops
from repro.models import model as model_mod

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_quant.json")

MODES = ("int8", "fp8_e4m3")
DRIFT_GATE = {"int8": 0.10, "fp8_e4m3": 0.20}
TOKS_GATE = 1.2          # int8 tokens/s floor vs bf16 at gate fills
JPT_GATE = 0.85          # int8 J/token ceiling vs bf16 at gate fills


def bench_cfg(smoke: bool):
    """Same GQA shape as BENCH_decode: 8 query heads over 4 KV heads of
    64, so the two artifacts measure the same serve-path layout.  The
    full run uses a larger cache than BENCH_decode (8192): the contrast
    under test is HBM/DRAM traffic per live byte, so the working set
    must comfortably exceed the LLC — at 4096 the bf16 cache is
    partially cache-resident and the measured ratio is contaminated by
    where the prefix happens to sit."""
    max_len = 2048 if smoke else 8192
    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_heads=8, num_kv_heads=4, head_dim=64)
    return cfg, max_len


def make_step(cfg, mode):
    """Jitted one-token flash-decode step for one cache precision.

    ``mode=None`` attends the bf16 cache; a quant mode attends codes +
    scales through the same dispatch (the lax fallback dequantizes with
    the kernel's block scales, the Pallas path in-register)."""
    scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)

    if mode is None:
        def step(q, k, v, ks, vs, cur):
            return da_ops.decode_attention(q, k, v, cur,
                                           softcap=cfg.attn_softcap,
                                           scale=scale)
    else:
        def step(q, k, v, ks, vs, cur):
            return da_ops.decode_attention(q, k, v, cur,
                                           softcap=cfg.attn_softcap,
                                           scale=scale, k_scale=ks,
                                           v_scale=vs)
    return jax.jit(step)


def run_impl(step_fn, operands, impl: str, batch: int, fills, steps: int,
             repeats: int):
    """Best-of-``repeats`` per fill on a private dummy-backend session."""
    q, k, v, ks, vs = operands

    def sweep(fill, record=None):
        cur = jnp.full((batch,), fill, jnp.int32)
        t0 = time.perf_counter()
        out = None
        for _ in range(steps):
            out = step_fn(q, k, v, ks, vs, cur)
            cur = cur + 1
        jax.block_until_ready(out)
        seconds = time.perf_counter() - t0
        if record is not None:
            record["seconds"] = seconds

    for fill in fills:          # warm jit + allocator, unmeasured
        sweep(fill)

    per_fill = {f: None for f in fills}
    for _ in range(repeats):
        fill_stats = {}
        with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
            mem = sess.add_exporter(pmt.MemoryExporter())
            for fill in fills:
                rec = {}
                with sess.region(f"quant/{impl}/fill{fill}",
                                 tokens=batch * steps):
                    sweep(fill, record=rec)
                fill_stats[fill] = rec
            sess.flush()
            for r in mem.records:
                fill = int(r.path.rsplit("fill", 1)[1])
                d = fill_stats[fill]
                d["joules"] = r.joules
                d["tokens"] = r.tokens
                d["tokens_per_s"] = r.tokens / max(d["seconds"], 1e-9)
                d["j_per_token"] = r.joules / max(r.tokens, 1)
        for f in fills:         # per-fill best wall clock across repeats
            if per_fill[f] is None \
                    or fill_stats[f]["seconds"] < per_fill[f]["seconds"]:
                per_fill[f] = fill_stats[f]
    return {"impl": impl, "fills": {str(f): per_fill[f] for f in fills}}


def cache_bytes_per_token(cfg, mode, max_len):
    """k+v bytes per cached token (codes + scales when quantized)."""
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    if mode is None:
        return 2 * kvh * hd * 2                       # bf16 k + v
    return 2 * kvh * (hd * 1 + 4)                     # codes + f32 scale


def measure_drift(mode):
    """Serve-path decode logit drift on reduced smollm, quant vs bf16
    cache — relative to the max |logit| (the number the accuracy gates
    in tests/test_quant_serve.py bound per arch)."""
    T = 32
    cfg = dataclasses.replace(configs.get_config("smollm-135m",
                                                 reduced=True),
                              dtype="float32")
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0,
                                cfg.vocab_size)
    logits = {}
    for kvq in (None, mode):
        c = dataclasses.replace(cfg, kv_quant=kvq)
        prefill, decode, _ = model_mod.make_serve_fns(
            c, cache_dtype=jnp.float32)
        _, caches = jax.jit(lambda p, b: prefill(p, b, T + 4))(
            params, {"tokens": tokens[:, :T - 1]})
        lg, _ = jax.jit(decode)(params, caches, tokens[:, T - 1:T],
                                jnp.asarray(T - 1, jnp.int32))
        logits[kvq] = np.asarray(lg)
    max_abs = float(np.max(np.abs(logits[mode] - logits[None])))
    ref_mag = float(np.max(np.abs(logits[None])))
    rel = max_abs / max(ref_mag, 1.0)
    return {"max_abs": max_abs, "ref_logit_mag": ref_mag, "relative": rel,
            "bound": DRIFT_GATE[mode], "ok": bool(rel < DRIFT_GATE[mode])}


def main(smoke=False, json_out=DEFAULT_JSON):
    cfg, max_len = bench_cfg(smoke)
    batch = 4
    steps = 16
    repeats = 3 if smoke else 5
    fills = [max_len // 8, max_len // 2, (3 * max_len) // 4]

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (batch, 1, cfg.num_heads, cfg.head_dim),
                          jnp.float32)
    kf = jax.random.normal(kk, (batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), jnp.float32)
    vf = jax.random.normal(kv, (batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), jnp.float32)

    operands = {"bf16": (q, kf.astype(jnp.bfloat16),
                         vf.astype(jnp.bfloat16), None, None)}
    for mode in MODES:
        kc, ks = quant.quantize(kf, mode)
        vc, vs = quant.quantize(vf, mode)
        operands[mode] = (q, kc, vc, ks, vs)

    results, drift = {}, {}
    for impl in ("bf16",) + MODES:
        step = make_step(cfg, None if impl == "bf16" else impl)
        results[impl] = run_impl(step, operands[impl], impl, batch, fills,
                                 steps, repeats)
    for mode in MODES:
        drift[mode] = measure_drift(mode)

    print("# quantized-KV A/B: bf16 cache vs int8 / fp8_e4m3 codes "
          "+ in-register dequant")
    print(f"{'impl':10s} {'fill':>6s} {'tok/s':>10s} {'J/token':>12s} "
          f"{'seconds':>9s}")
    speedups = {m: {} for m in MODES}
    for fill in fills:
        f = str(fill)
        for impl in ("bf16",) + MODES:
            d = results[impl]["fills"][f]
            print(f"{impl:10s} {fill:6d} {d['tokens_per_s']:10.1f} "
                  f"{d['j_per_token']:12.8f} {d['seconds']:9.3f}")
        base = results["bf16"]["fills"][f]
        for mode in MODES:
            d = results[mode]["fills"][f]
            speedups[mode][f] = {
                "tokens_per_s": d["tokens_per_s"]
                / max(base["tokens_per_s"], 1e-9),
                "j_per_token_ratio": d["j_per_token"]
                / max(base["j_per_token"], 1e-12),
            }
            s = speedups[mode][f]
            print(f"#          {fill:6d} {mode} {s['tokens_per_s']:.2f}x "
                  f"tokens/s, {s['j_per_token_ratio']:.2f}x J/token")

    for mode in MODES:
        dr = drift[mode]
        print(f"# drift {mode}: {dr['max_abs']:.5f} abs "
              f"({dr['relative']:.4f} of max |logit| {dr['ref_logit_mag']:.2f}"
              f", bound {dr['bound']}) -> {'OK' if dr['ok'] else 'FAIL'}")

    gate_fills = [f for f in fills if f >= max_len // 2]
    perf_met = all(
        speedups["int8"][str(f)]["tokens_per_s"] >= TOKS_GATE
        and speedups["int8"][str(f)]["j_per_token_ratio"] <= JPT_GATE
        for f in gate_fills)
    drift_met = all(drift[m]["ok"] for m in MODES)
    # the smoke cache (max_len 2048) is small enough to sit in LLC, so
    # the bandwidth win the perf gate measures may not materialize; the
    # smoke leg gates on drift only (validate_bench applies the same
    # relaxation) while the committed full run takes both gates.
    target_met = drift_met if smoke else (perf_met and drift_met)
    print(f"# gate (int8 >= {TOKS_GATE}x tok/s, <= {JPT_GATE}x J/token at "
          f"fills {gate_fills}{' [informational: smoke]' if smoke else ''}; "
          f"drift under bounds): {'PASS' if target_met else 'FAIL'}")

    if json_out:
        payload = {
            "bench": "pmt_quant",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "shape": "decode attention layer, one token vs live "
                         "cache, per-row cur_len vector",
                "heads": cfg.num_heads,
                "kv_heads": cfg.num_kv_heads,
                "head_dim": cfg.head_dim,
                "backend": "dummy",
                "impl_backend": jax.default_backend(),
                "batch": batch,
                "max_len": max_len,
                "steps_per_fill": steps,
                "fills": fills,
                "gate_fills": gate_fills,
                "tokens_per_s_gate": TOKS_GATE,
                "j_per_token_gate": JPT_GATE,
                "cache_bytes_per_token": {
                    impl: cache_bytes_per_token(
                        cfg, None if impl == "bf16" else impl, max_len)
                    for impl in ("bf16",) + MODES},
            },
            "bf16": results["bf16"],
            "int8": results["int8"],
            "fp8_e4m3": results["fp8_e4m3"],
            "speedups": speedups,
            "logit_drift": drift,
            "perf_met": bool(perf_met),
            "drift_met": bool(drift_met),
            "target_met": bool(target_met),
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return bool(target_met)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smaller cache, fewer steps)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_quant.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
