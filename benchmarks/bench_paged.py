"""Paged KV cache A/B — contiguous per-slot rows vs block-paged pools.

Contiguous serving reserves ``max_len`` cache rows per slot, so the
cache-memory budget caps concurrency at ``budget / max_len`` even when
requests use a fraction of the reservation.  Paging allocates pages for
what a request *actually* needs (prompt + generation, rounded up to the
page), so the same bytes admit more concurrent requests — and the radix
prefix tree turns retired prompts into copy-free cache hits for later
requests sharing a prefix.

Three comparisons on the same bench-scaled model and workload, each on
a private dummy-backend session (constant watts: J/token is wall-time
per token, which is what the layout changes):

  * **equal batch** — contiguous vs paged at the same batch and a full
    pool.  The layout must be ~free: paged J/token <= 1.05x contiguous.
    The contiguous leg uses the length-aware ("flash") decode path so
    both engines attend only written positions — apples to apples.
  * **fixed cache budget** — paged serves the workload with 2x the
    slots on the *same page budget* as the contiguous leg (requests
    occupy pages proportional to their actual length, not ``max_len``).
    Mean admitted concurrency (Little's law: request-span busy seconds
    over wall seconds) must reach >= 1.5x the contiguous leg's.
  * **prefix reuse** — a workload sharing a long system prompt, served
    cold then warm through the same engine.  The warm run must take
    prefix hits, accrue ``saved_prefill_joules > 0`` (priced at the
    J/token the engine learned from the cold run's resolved prefill
    spans), and cut the mean prefill (time-to-first-token) latency
    below the cold run's.

Pass criteria (written into BENCH_paged.json, validated by CI via
benchmarks/validate_bench.py):
  * paged_equal J/token <= 1.05x contiguous;
  * paged_big mean concurrency >= 1.5x contiguous on the same page
    budget, all requests completing;
  * warm prefix run: saved_prefill_joules > 0, prefix_hit_tokens > 0,
    mean prefill latency < cold mean.

Usage: PYTHONPATH=src python benchmarks/bench_paged.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_paged.json")

PAGE = 32


def bench_cfg():
    """Bench-local scaled config: big enough that chunks/steps are
    compute-bound on CPU (the A/B measures layout, not dispatch
    overhead), fp32 throughout (CPU has no native bf16)."""
    return dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
        vocab_size=1024, attn_chunk=128, prefill_chunk=64)


def make_workload(n_requests, plen_lo, plen_hi, max_new, vocab, seed=0,
                  shared_prefix=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=shared_prefix).tolist()
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(plen_lo, plen_hi + 1))
        reqs.append(Request(
            prompt=prefix + rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=max_new))
    return reqs


def run_leg(eng, workload, label):
    """One measured ``generate()`` on a private session; returns
    throughput/energy plus the span-derived concurrency and prefill
    latency the gates consume."""
    with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        if hasattr(eng, "on_record"):
            unsub = mem.subscribe(eng.on_record)
        eng.session = sess
        reqs = [dataclasses.replace(r) for r in workload]
        t0 = time.perf_counter()
        done = eng.generate(reqs)
        seconds = time.perf_counter() - t0
        eng.session = None
        sess.flush()
        unsub()
    tokens = sum(len(r.out) for r in done)
    assert all(len(r.out) == r.max_new_tokens for r in done), (
        f"{label}: not every request completed")
    agg_j = sum(r.joules for r in mem.records
                if r.path.startswith("serve/batch"))
    req_busy_s = sum(r.seconds for r in mem.records
                     if r.path.startswith("serve/req")
                     and "/" not in r.path[len("serve/req"):])
    prefill_s = [r.seconds for r in mem.records
                 if r.path.endswith("/prefill")]
    leg = {
        "label": label,
        "batch_slots": eng.batch,
        "seconds": seconds,
        "tokens": tokens,
        "tokens_per_s": tokens / max(seconds, 1e-9),
        "joules": agg_j,
        "j_per_token": agg_j / max(tokens, 1),
        "mean_concurrency": req_busy_s / max(seconds, 1e-9),
        "mean_prefill_s": (sum(prefill_s) / len(prefill_s))
        if prefill_s else 0.0,
    }
    if eng.kv_layout == "paged":
        kc = eng.stats()["kv_cache"]
        leg["kv_cache"] = {k: kc[k] for k in
                           ("page_size", "pages_total", "pages_free",
                            "pages_used", "prefix_hit_tokens",
                            "saved_prefill_joules")}
    return leg


def main(smoke=False, json_out=DEFAULT_JSON):
    cfg = bench_cfg()
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 256
    batch_c = 2
    # the contiguous leg's cache budget, in pages
    budget_pages = batch_c * max_len // PAGE
    n_requests = 8 if smoke else 16
    plen_lo, plen_hi = 56, 72
    max_new = 6 if smoke else 3
    # the smoke run is a CI liveness/schema check on a workload too
    # small to amortize per-dispatch noise in the equal-batch J/token
    # ratio; the committed full run is the real A/B at the tight gate.
    jpt_gate = 1.25 if smoke else 1.05
    workload = make_workload(n_requests, plen_lo, plen_hi, max_new,
                             cfg.vocab_size)

    def warm(eng):
        eng.generate([Request(prompt=[1] * (cfg.prefill_chunk + 1),
                              max_new_tokens=2)])

    # -- leg 1: contiguous baseline (length-aware decode) ------------------
    eng_c = ServeEngine(cfg, params, batch_size=batch_c, max_len=max_len,
                        decode_attn_impl="flash", cache_dtype=jnp.float32)
    warm(eng_c)
    contiguous = run_leg(eng_c, workload, "contiguous")

    # -- leg 2: paged, equal batch, full pool ------------------------------
    eng_e = ServeEngine(cfg, params, batch_size=batch_c, max_len=max_len,
                        kv_layout="paged", kv_page_size=PAGE,
                        prefix_cache=False, cache_dtype=jnp.float32)
    warm(eng_e)
    paged_equal = run_leg(eng_e, workload, "paged_equal")

    # -- leg 3: paged, 2x slots on the contiguous leg's page budget --------
    eng_b = ServeEngine(cfg, params, batch_size=2 * batch_c,
                        max_len=max_len, kv_layout="paged",
                        kv_page_size=PAGE, kv_pool_pages=budget_pages,
                        prefix_cache=False, cache_dtype=jnp.float32)
    warm(eng_b)
    paged_big = run_leg(eng_b, workload, "paged_big")

    # -- leg 4: prefix reuse, cold then warm -------------------------------
    shared = make_workload(n_requests, 8, 12, max_new, cfg.vocab_size,
                           seed=1, shared_prefix=3 * PAGE)
    eng_p = ServeEngine(cfg, params, batch_size=batch_c, max_len=max_len,
                        kv_layout="paged", kv_page_size=PAGE,
                        cache_dtype=jnp.float32)
    warm(eng_p)
    prefix_cold = run_leg(eng_p, shared, "prefix_cold")
    prefix_warm = run_leg(eng_p, shared, "prefix_warm")

    jpt_ratio = paged_equal["j_per_token"] / max(contiguous["j_per_token"],
                                                 1e-12)
    conc_ratio = paged_big["mean_concurrency"] \
        / max(contiguous["mean_concurrency"], 1e-9)
    saved_j = prefix_warm["kv_cache"]["saved_prefill_joules"]
    hit_tokens = prefix_warm["kv_cache"]["prefix_hit_tokens"]
    ttft_ratio = prefix_warm["mean_prefill_s"] \
        / max(prefix_cold["mean_prefill_s"], 1e-9)

    jpt_ok = jpt_ratio <= jpt_gate
    conc_ok = conc_ratio >= 1.5
    prefix_ok = saved_j > 0.0 and hit_tokens > 0 and ttft_ratio < 1.0
    target_met = bool(jpt_ok and conc_ok and prefix_ok)

    print("# paged KV A/B: contiguous vs block-paged pools")
    print(f"{'leg':14s} {'slots':>5s} {'tok/s':>8s} {'J/token':>9s} "
          f"{'conc':>6s} {'prefill ms':>11s}")
    for d in (contiguous, paged_equal, paged_big, prefix_cold, prefix_warm):
        print(f"{d['label']:14s} {d['batch_slots']:5d} "
              f"{d['tokens_per_s']:8.1f} {d['j_per_token']:9.4f} "
              f"{d['mean_concurrency']:6.2f} "
              f"{d['mean_prefill_s'] * 1e3:11.2f}")
    print(f"# equal batch: paged J/token {jpt_ratio:.3f}x contiguous "
          f"(<= {jpt_gate:.2f} {'OK' if jpt_ok else 'FAIL'})")
    print(f"# fixed {budget_pages}-page budget: {conc_ratio:.2f}x mean "
          f"concurrency (>= 1.5 {'OK' if conc_ok else 'FAIL'})")
    print(f"# prefix reuse: {hit_tokens} tokens reused, {saved_j:.2f} J "
          f"prefill saved, warm TTFT {ttft_ratio:.2f}x cold "
          f"({'OK' if prefix_ok else 'FAIL'})")
    print(f"# {'PASS' if target_met else 'FAIL'}")

    if json_out:
        payload = {
            "bench": "pmt_paged",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "arch": "smollm-135m (bench-scaled reduced cfg: 4L/d256, "
                        "fp32)",
                "backend": "dummy",
                "n_requests": n_requests,
                "batch": batch_c,
                "max_len": max_len,
                "page_size": PAGE,
                "budget_pages": budget_pages,
                "prompt_lengths": [plen_lo, plen_hi],
                "max_new_tokens": max_new,
                "prefill_chunk": cfg.prefill_chunk,
                "shared_prefix_tokens": 3 * PAGE,
            },
            "contiguous": contiguous,
            "paged_equal": paged_equal,
            "paged_big": paged_big,
            "prefix_cold": prefix_cold,
            "prefix_warm": prefix_warm,
            "jpt_ratio_paged_vs_contiguous": jpt_ratio,
            "concurrency_ratio_fixed_budget": conc_ratio,
            "saved_prefill_joules": saved_j,
            "prefix_hit_tokens": hit_tokens,
            "warm_ttft_ratio": ttft_ratio,
            "target_met": target_met,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return target_met


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_paged.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
