"""Measurement-plane chaos run — blackout/flap/recovery under load.

Three legs, written into BENCH_faults.json (validated by CI via
benchmarks/validate_bench.py):

* **overhead** — the supervised read path's tax on a healthy backend.
  ``SensorSupervisor`` wraps ``cpuutil`` (a real ``/proc/stat`` read,
  tens of microseconds — the dummy's ~2 us would make any Python-level
  wrapper look catastrophic) and races it against a bare instance.
  Gate: supervised/raw time ratio <= 1.10.

* **chaos** — the tentpole integration gate.  A governed serve run on a
  load-coupled fault-injected sensor is driven through a scripted
  mid-run blackout (every read raises), then an intermittent flap, then
  full recovery; fault windows are scaled from a healthy run's measured
  duration so they land mid-run at any machine speed.  Gates: the
  sampler thread never dies, every request completes in full (tokens
  match the healthy run), spans straddling the blackout resolve
  ``degraded`` (never silently interpolated), health transitions are
  observed, the governor's fail-closed stale-signal policy engages, and
  after recovery the smoothed window power is re-held under
  ``cap * 1.05``.

* **failover** — the same blackout with a healthy fallback in the
  supervisor chain: reads fail over (then back), the ring never opens a
  coverage gap, and no span resolves degraded — redundancy turns a
  blackout into a non-event.

Usage: PYTHONPATH=src python benchmarks/bench_faults.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.core.backends.dummy import DummySensor
from repro.core.faults import Fault, FaultInjectingSensor
from repro.core.sampler import SamplerCoverageGap, SamplerReadError
from repro.core.supervisor import SensorSupervisor
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine
from repro.serve.governor import PowerGovernor
from repro.telemetry import PowerRecorder

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_faults.json")

IDLE_W = 50.0
SLOT_W = 15.0
OVERHEAD_LIMIT = 1.10
CAP_TOL = 1.05


# -- leg 1: supervised read overhead ----------------------------------------

def _time_reads(sensor, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        sensor.read_raw()
    return time.perf_counter() - t0


def _bench_pair(raw, sup, n: int, rounds: int = 11):
    """Per-read seconds for both sensors plus a drift-immune overhead
    ratio: raw and supervised rounds run back-to-back as pairs
    (alternating order, so neither side systematically runs on a
    warmer cache), the ratio is taken *within* each pair so
    CPU-frequency drift between rounds cancels, and the best pair wins
    — timing noise is strictly additive, so the minimum paired ratio
    is the estimate least polluted by scheduler interference."""
    raw.read_raw()                       # prime lazy state
    sup.read_raw()
    pairs = []
    for i in range(rounds):
        if i % 2 == 0:
            r = _time_reads(raw, n)
            s = _time_reads(sup, n)
        else:
            s = _time_reads(sup, n)
            r = _time_reads(raw, n)
        pairs.append((s / max(r, 1e-12), r, s))
    ratio, r, s = min(pairs)
    return ratio, r / n, s / n


def run_overhead(smoke: bool) -> dict:
    n = 2000 if smoke else 4000
    backend = "cpuutil"
    try:
        raw = pmt.create(backend)
        raw.read_raw()
    except Exception:
        # No /proc/stat on this host: fall back to a calibrated spin
        # read so the ratio still measures wrapper cost against a
        # realistically priced backend.
        backend = "spin10us"

        def spin_sample(self):
            end = time.perf_counter() + 10e-6
            while time.perf_counter() < end:
                pass
            return pmt.Sample(watts=50.0)

        raw = DummySensor(watts=50.0)
        raw._sample = spin_sample.__get__(raw)
        sup_inner = DummySensor(watts=50.0)
        sup_inner._sample = spin_sample.__get__(sup_inner)
    else:
        sup_inner = pmt.create(backend)
    sup = SensorSupervisor([sup_inner])
    ratio, raw_s, sup_s = _bench_pair(raw, sup, n)
    return {
        "backend": backend,
        "reads": n,
        "raw_us_per_read": raw_s * 1e6,
        "supervised_us_per_read": sup_s * 1e6,
        "ratio": ratio,
        "ok": bool(ratio <= OVERHEAD_LIMIT),
    }


# -- legs 2/3: chaos + failover serve runs ----------------------------------

def make_workload(n_requests: int, vocab: int, max_new_lo: int,
                  max_new_hi: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(
        prompt=rng.integers(0, vocab,
                            size=int(rng.integers(17, 48))).tolist(),
        max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1)))
        for _ in range(n_requests)]


def window_max_watts(series, window_s: float, t_start: float) -> float:
    """Max sliding-window mean over samples at/after ``t_start``."""
    worst = 0.0
    for i, (t_i, _w) in enumerate(series):
        if t_i < t_start:
            continue
        win = [w for t, w in series[max(0, i - 512):i + 1]
               if t >= t_i - window_s]
        if win:
            worst = max(worst, sum(win) / len(win))
    return worst


def run_serve(cfg, params, workload, batch: int, max_len: int, chunk: int,
              cap: float, window_s: float, fallback: bool,
              fault_windows=None):
    """One governed serve run on a supervised, fault-injectable
    load-coupled sensor.  ``fault_windows`` is ``(blackout, flap)`` time
    pairs relative to arm; None runs healthy."""
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      session=None, prefill_chunk=chunk,
                      cache_dtype=jnp.float32)
    eng.generate([Request(prompt=[1] * (chunk + 1), max_new_tokens=2)])

    inner = DummySensor(watts_fn=lambda t: IDLE_W + SLOT_W * eng.live_slots)
    plan = []
    if fault_windows is not None:
        (b0, b1), (f0, f1) = fault_windows
        plan = [Fault("error", t0_s=b0, t1_s=b1),
                Fault("flap", t0_s=f0, t1_s=f1, period=3, duty=1)]
    fis = FaultInjectingSensor(inner, plan=plan)
    chain = [fis] + ([DummySensor(
        watts_fn=lambda t: IDLE_W + SLOT_W * eng.live_slots)]
        if fallback else [])
    sup = SensorSupervisor(chain, retries=1, backoff_s=0.001,
                           breaker_threshold=3, breaker_cooldown_s=0.05)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SamplerReadError)
        warnings.simplefilter("ignore", SamplerCoverageGap)
        with pmt.Session([sup], pool=pmt.SensorPool(),
                         period_s=0.002) as sess:
            mem = sess.add_exporter(pmt.MemoryExporter())
            ring = dict(sess.samplers())[sup.name]
            with PowerRecorder(poll_period_s=0.01).attach(
                    sess, exporter=mem) as rec:
                gov = PowerGovernor(rec, cap_watts=cap, window_s=window_s,
                                    signal_ttl_s=0.2, fail_mode="closed")
                eng.session = sess
                eng.governor = gov
                reqs = [dataclasses.replace(r) for r in workload]
                fis.arm()
                t_arm = sup.now()
                t0 = time.perf_counter()
                done = eng.generate(reqs)
                seconds = time.perf_counter() - t0
                eng.session = None
                eng.governor = None
                sess.flush()
                rec.poll_once()

                thread_alive = ring.is_alive()
                series = rec.watts_series(sup.name).get(sup.name, [])
                health_events = [e._asdict() for e in rec.health_events()]
                gov_stats = gov.stats()
                gov_actions = [d.action for d in gov.decisions]
                ring_health = ring.health()
                sess_stats = sess.stats()
                gov.close()
    return {
        "seconds": seconds,
        "t_arm": t_arm,
        "tokens": sum(len(r.out) for r in done),
        "all_requests_complete": bool(
            all(len(r.out) == r.max_new_tokens for r in done)),
        "sampler_thread_alive": bool(thread_alive),
        "read_errors": ring_health["read_errors"],
        "coverage_gaps": ring_health["gaps"],
        "degraded_records": sum(1 for r in mem.records if r.degraded),
        "total_records": len(mem.records),
        "session_degraded_spans": sess_stats["degraded"],
        "health_events": health_events,
        "supervisor": sup.health(),
        "governor": {k: gov_stats[k] for k in
                     ("throttle_decisions", "signal_ttl_s", "fail_mode")},
        "governor_actions": sorted(set(gov_actions)),
        "series": series,
    }


def main(smoke=False, json_out=DEFAULT_JSON):
    overhead = run_overhead(smoke)

    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
        vocab_size=1024, attn_chunk=128)
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    chunk = 32
    batch = 4
    window_s = 0.1
    cap = IDLE_W + 2.5 * SLOT_W
    # The chaos timeline (blackout -> flap -> recovery -> recap) must
    # fit *inside* the run with slack on both ends, so the workload is
    # sized for a multi-second governed run even in smoke mode.
    n_requests = 6 if smoke else 10
    max_new_lo, max_new_hi = (72, 104) if smoke else (96, 160)
    max_len = 64 + max_new_hi
    workload = make_workload(n_requests, cfg.vocab_size, max_new_lo,
                             max_new_hi)

    # Healthy run first: its duration T scales the fault windows so the
    # blackout lands (and *ends*) mid-run on any machine.  The blackout
    # must outlive the governor's signal TTL (0.2 s) to force the
    # fail-closed stale episode.
    healthy = run_serve(cfg, params, workload, batch, max_len, chunk, cap,
                        window_s, fallback=False, fault_windows=None)
    T = healthy["seconds"]
    blackout = (0.25 * T, 0.25 * T + max(0.35, 0.2 * T))
    flap = (blackout[1] + 0.1, blackout[1] + 0.1 + max(0.2, 0.1 * T))
    fault_windows = (blackout, flap)

    chaos = run_serve(cfg, params, workload, batch, max_len, chunk, cap,
                      window_s, fallback=False,
                      fault_windows=fault_windows)
    failover = run_serve(cfg, params, workload, batch, max_len, chunk, cap,
                         window_s, fallback=True,
                         fault_windows=fault_windows)

    # -- gates ---------------------------------------------------------------
    # Re-ramp allowance after the last fault clears: the governor
    # re-admits the requests it deferred during the fail-closed episode
    # and needs a few windows to settle them under the cap, the same
    # settling a cold start gets in bench_governor.
    recap_from = chaos["t_arm"] + flap[1] + 5 * window_s
    tail = [s for s in chaos["series"] if s[0] >= recap_from]
    recap_peak = window_max_watts(chaos["series"], window_s, recap_from)
    chaos_gates = {
        "all_requests_complete": chaos["all_requests_complete"]
        and chaos["tokens"] == healthy["tokens"],
        "sampler_thread_alive": chaos["sampler_thread_alive"],
        "blackout_hit": chaos["read_errors"] > 0
        and chaos["coverage_gaps"] >= 1,
        "degraded_spans_marked": chaos["degraded_records"] > 0
        and chaos["session_degraded_spans"] > 0,
        "health_transitions_observed": len(chaos["health_events"]) >= 2,
        "fail_safe_engaged": "signal_stale" in chaos["governor_actions"]
        and "signal_fresh" in chaos["governor_actions"],
        "governor_recaps_after_recovery": bool(tail)
        and recap_peak <= cap * CAP_TOL,
    }
    failover_gates = {
        "all_requests_complete": failover["all_requests_complete"],
        "failed_over_and_back":
            failover["supervisor"]["counters"]["failovers"] >= 1
            and failover["supervisor"]["counters"]["failbacks"] >= 1,
        "no_coverage_gap": failover["coverage_gaps"] == 0
        and failover["degraded_records"] == 0,
    }
    target_met = bool(overhead["ok"] and all(chaos_gates.values())
                      and all(failover_gates.values()))

    # -- report --------------------------------------------------------------
    print(f"# measurement-plane chaos (cap {cap:.0f} W, "
          f"blackout {blackout[0]:.2f}-{blackout[1]:.2f}s, "
          f"flap {flap[0]:.2f}-{flap[1]:.2f}s of a {T:.2f}s healthy run)")
    print(f"overhead[{overhead['backend']}]: raw "
          f"{overhead['raw_us_per_read']:.2f} us, supervised "
          f"{overhead['supervised_us_per_read']:.2f} us -> "
          f"{overhead['ratio']:.3f}x (limit {OVERHEAD_LIMIT:.2f}x, "
          f"{'PASS' if overhead['ok'] else 'FAIL'})")
    for name, run, gates in (("chaos", chaos, chaos_gates),
                             ("failover", failover, failover_gates)):
        print(f"{name}: {run['tokens']} tokens in {run['seconds']:.2f}s, "
              f"{run['read_errors']} read errors, "
              f"{run['coverage_gaps']} gaps, "
              f"{run['degraded_records']}/{run['total_records']} degraded "
              f"records, {len(run['health_events'])} health events, "
              f"supervisor {run['supervisor']['state']}")
        for g, ok in gates.items():
            print(f"  {'PASS' if ok else 'FAIL'} {g}")
    print(f"# recap peak after recovery: {recap_peak:.1f} W vs "
          f"{cap * CAP_TOL:.1f} W allowed; overall "
          f"{'PASS' if target_met else 'FAIL'}")

    if json_out:
        def slim(run):
            d = dict(run)
            d["watts_samples"] = len(d.pop("series"))
            return d
        payload = {
            "bench": "pmt_faults",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "arch": "smollm-135m (bench-scaled reduced cfg: 4L/d256, "
                        "fp32)",
                "backend": "dummy (load-coupled) via FaultInjectingSensor "
                           "+ SensorSupervisor",
                "idle_watts": IDLE_W,
                "slot_watts": SLOT_W,
                "cap_watts": cap,
                "window_s": window_s,
                "n_requests": n_requests,
                "batch": batch,
                "max_len": max_len,
                "prefill_chunk": chunk,
                "max_new_tokens": [max_new_lo, max_new_hi],
                "blackout_s": list(blackout),
                "flap_s": list(flap),
            },
            "overhead": overhead,
            "healthy": slim(healthy),
            "chaos": slim(chaos),
            "failover": slim(failover),
            "chaos_gates": chaos_gates,
            "failover_gates": failover_gates,
            "recap_peak_window_watts": recap_peak,
            "target_met": target_met,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return target_met


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer/shorter requests)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_faults.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
