"""Paper sampling-rate claim — backend-dependent native periods
(NVML sustains ~10 ms, RAPL ~500 ms).

Dump-mode writes (timestamp, watts, joules) records at the backend's
native period; we run the dump thread against sensors configured with the
paper's two rates and verify the achieved inter-sample period tracks the
nominal one, and that the dump-file energy integral matches
measurement-mode over the same window.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

import repro.core as pmt


def main(csv=False):
    rows = []
    for label, period in (("nvml_like", 0.010), ("rapl_like", 0.100)):
        sensor = pmt.create("dummy", watts_fn=lambda t: 50.0 + 10.0 * (t % 0.2) / 0.2)
        start = sensor.read()
        with tempfile.NamedTemporaryFile(suffix=".pmt", delete=False) as f:
            path = f.name
        sensor.start_dump_thread(path, period_s=period)
        time.sleep(max(20 * period, 0.3))
        sensor.stop_dump_thread()
        end = sensor.read()

        header, records = pmt.read_dump(path)
        ts = np.array([r.t_rel_s for r in records])
        dt = np.diff(ts)
        achieved = float(np.median(dt))
        dump_joules = pmt.total_joules(records)
        mm_joules = pmt.joules(start, end)
        rel = abs(dump_joules - mm_joules) / max(mm_joules, 1e-9)
        rows.append((label, period, achieved, len(records), rel))
        os.unlink(path)

    print("# PMT dump-mode sampling (paper: NVML ~10 ms, RAPL ~500 ms)")
    print(f"{'backend':12s} {'nominal_s':>10s} {'achieved_s':>11s} "
          f"{'samples':>8s} {'energy_err':>11s}")
    for label, nominal, achieved, n, rel in rows:
        print(f"{label:12s} {nominal:10.3f} {achieved:11.4f} {n:8d} "
              f"{rel:11.4f}")
    if csv:
        for label, nominal, achieved, n, rel in rows:
            print(f"sampling_{label},{achieved*1e6:.0f},"
                  f"nominal_us={nominal*1e6:.0f};energy_err={rel:.4f}")
    return rows


if __name__ == "__main__":
    main()
