"""Shared schema validation for the committed BENCH_*.json artifacts.

One validator per bench family, dispatched on the payload's ``bench``
field — the single source of truth the CI bench-smoke matrix job (and
anyone regenerating a benchmark locally) runs instead of four copies of
inline assert blocks.

Usage: python benchmarks/validate_bench.py BENCH_overhead.json [...]
Exits non-zero on the first failing file.
"""
from __future__ import annotations

import json
import sys


def _positive_float(d, *keys, ctx=""):
    for key in keys:
        v = d[key]
        assert isinstance(v, float) and v > 0, (ctx, key, v)


def validate_overhead(d):
    for mode in ("list_core_sync", "array_core_sync", "array_core_async"):
        us = d["modes"][mode]["region_close_us"]
        assert isinstance(us, float) and us > 0, (mode, us)
    assert d["speedup_async_vs_list_core"] > 0
    for core in ("array_core", "list_core"):
        assert d["tick_jitter"][core]["samples"] > 0
    rt = d["resolve_throughput"]
    assert rt["vectorized_spans_per_s"] > 0
    assert rt["max_abs_err_j"] < 1e-9
    return (f"async {d['speedup_async_vs_list_core']:.1f}x vs list core")


def validate_serve(d):
    for mode in ("wave", "continuous"):
        _positive_float(d[mode], "tokens_per_s", "j_per_token", "seconds",
                        "joules", ctx=mode)
        assert d[mode]["tokens"] > 0
    assert d["request_token_sum_matches"] is True
    assert d["continuous"]["request_token_sum"] == d["continuous"]["tokens"]
    assert d["decode_compiles_once"] is True
    assert d["speedup_tokens_per_s"] > 0
    assert d["jpt_improvement"] > 0
    assert d["target_met"] is True, "continuous did not beat waves"
    return (f"{d['speedup_tokens_per_s']:.2f}x tokens/s, "
            f"{d['jpt_improvement']:.2f}x lower J/token")


def validate_decode(d):
    fills = d["workload"]["fills"]
    gate = d["workload"]["gate_fills"]
    assert gate and all(f >= d["workload"]["max_len"] // 2 for f in gate)
    for impl in ("dense", "flash"):
        for f in fills:
            _positive_float(d[impl]["fills"][str(f)], "tokens_per_s",
                            "j_per_token", "seconds", "joules",
                            ctx=(impl, f))
            assert d[impl]["fills"][str(f)]["tokens"] > 0
    for f in gate:
        s = d["speedups"][str(f)]
        assert s["tokens_per_s"] >= 1.0, (f, s)
        assert s["j_per_token_improvement"] >= 1.0, (f, s)
    assert d["target_met"] is True, "flash did not beat dense"
    half = d["speedups"][str(gate[0])]
    return (f"{half['tokens_per_s']:.2f}x tokens/s, "
            f"{half['j_per_token_improvement']:.2f}x lower J/token at "
            f"fill {gate[0]}")


def validate_prefill(d):
    for mode in ("blocking", "chunked"):
        _positive_float(d[mode], "tokens_per_s", "j_per_token", "seconds",
                        "joules", ctx=mode)
        assert d[mode]["tokens"] > 0
        assert d[mode]["request_token_sum"] == d[mode]["tokens"]
        assert d[mode]["max_phase_split_rel_err"] <= 0.02, mode
    assert d["chunked"]["prefill_chunk"] > 0
    assert d["blocking"]["prefill_chunk"] == 0
    cc = d["chunked"]["compile_counts"]
    assert cc["prefill_chunk"] == 1 and cc["decode"] == 1 \
        and cc["prefill"] == 0, cc
    assert d["chunked_prefill_compiles_once"] is True
    assert d["phase_split_sums_to_total"] is True
    assert d["stall_p95_improved"] is True
    assert d["speedup_tokens_per_s"] >= 1.2, d["speedup_tokens_per_s"]
    assert d["jpt_improvement"] >= 1.2, d["jpt_improvement"]
    assert d["target_met"] is True, "chunked did not beat blocking"
    return (f"{d['speedup_tokens_per_s']:.2f}x tokens/s, "
            f"{d['jpt_improvement']:.2f}x lower J/token, stall p95 "
            f"{d['chunked']['p95_decode_stall_s'] * 1e3:.1f} vs "
            f"{d['blocking']['p95_decode_stall_s'] * 1e3:.1f} ms")


def validate_governor(d):
    cap = d["workload"]["cap_watts"]
    assert isinstance(cap, float) and cap > 0
    for mode in ("baseline", "uncapped", "capped"):
        _positive_float(d[mode], "tokens_per_s", "j_per_token", "seconds",
                        "joules", "peak_window_watts", ctx=mode)
        assert d[mode]["tokens"] > 0
        assert d[mode]["all_requests_complete"] is True, mode
        assert d[mode]["watts_samples"] > 0, mode
    assert d["baseline"]["governor"] is None
    assert d["uncapped"]["governor"]["cap_watts"] is None
    assert d["uncapped"]["governor"]["throttle_decisions"] == 0
    assert d["capped"]["governor"]["cap_watts"] == cap
    assert d["capped"]["governor"]["throttle_decisions"] >= 1
    # the headline gate: smoothed power stays under cap + 5% while the
    # uncapped run proves the cap was actually binding
    assert d["capped"]["peak_window_watts"] <= cap * 1.05, \
        (d["capped"]["peak_window_watts"], cap)
    assert d["uncapped"]["peak_window_watts"] > cap * 1.05
    assert d["cap_held"] is True
    assert d["cap_binding"] is True
    assert d["liveness_ok"] is True
    assert d["observer_overhead_ok"] is True
    assert d["governor_acted"] is True
    assert d["target_met"] is True, "governor did not hold the cap"
    return (f"cap {cap:.0f} W held: capped peak "
            f"{d['capped']['peak_window_watts']:.1f} W vs uncapped "
            f"{d['uncapped']['peak_window_watts']:.1f} W, "
            f"{d['capped']['tokens_per_s'] / d['baseline']['tokens_per_s']:.2f}x "
            f"baseline tokens/s, all requests complete")


def validate_faults(d):
    ov = d["overhead"]
    assert isinstance(ov["ratio"], float) and 0 < ov["ratio"] <= 1.10, ov
    assert ov["ok"] is True
    assert d["workload"]["blackout_s"][0] < d["workload"]["blackout_s"][1]
    assert d["workload"]["blackout_s"][1] <= d["workload"]["flap_s"][0]
    for run in ("healthy", "chaos", "failover"):
        r = d[run]
        _positive_float(r, "seconds", ctx=run)
        assert r["tokens"] > 0 and r["all_requests_complete"] is True, run
        assert r["sampler_thread_alive"] is True, run
        assert r["watts_samples"] > 0, run
    assert d["chaos"]["tokens"] == d["healthy"]["tokens"]
    # the blackout actually happened and was surfaced, not papered over
    assert d["chaos"]["read_errors"] > 0
    assert d["chaos"]["coverage_gaps"] >= 1
    assert d["chaos"]["degraded_records"] > 0
    assert d["chaos"]["session_degraded_spans"] > 0
    assert len(d["chaos"]["health_events"]) >= 2
    assert "signal_stale" in d["chaos"]["governor_actions"]
    assert "signal_fresh" in d["chaos"]["governor_actions"]
    cap = d["workload"]["cap_watts"]
    assert d["recap_peak_window_watts"] <= cap * 1.05
    # with a fallback in the chain the same blackout is a non-event
    fo = d["failover"]["supervisor"]["counters"]
    assert fo["failovers"] >= 1 and fo["failbacks"] >= 1, fo
    assert d["failover"]["coverage_gaps"] == 0
    assert d["failover"]["degraded_records"] == 0
    for gates in ("chaos_gates", "failover_gates"):
        for name, ok in d[gates].items():
            assert ok is True, (gates, name)
    assert d["target_met"] is True, "fault-tolerance gates not met"
    return (f"supervised read {ov['ratio']:.3f}x raw; blackout survived "
            f"({d['chaos']['read_errors']} read errors, "
            f"{d['chaos']['degraded_records']} degraded records, cap "
            f"re-held at {d['recap_peak_window_watts']:.1f} W); failover "
            f"{fo['failovers']}/{fo['failbacks']} over/back with 0 gaps")


def validate_paged(d):
    w = d["workload"]
    assert w["page_size"] > 0 and w["budget_pages"] > 0
    legs = ("contiguous", "paged_equal", "paged_big", "prefix_cold",
            "prefix_warm")
    for leg in legs:
        _positive_float(d[leg], "tokens_per_s", "j_per_token", "seconds",
                        "joules", ctx=leg)
        assert d[leg]["tokens"] > 0
        assert d[leg]["mean_concurrency"] > 0, leg
    for leg in legs[1:]:
        kc = d[leg]["kv_cache"]
        assert kc["page_size"] == w["page_size"]
        assert kc["pages_free"] + kc["pages_used"] == kc["pages_total"], leg
    # equal batch: the layout is ~free (smoke workloads are too small to
    # amortize per-dispatch noise, so the gate relaxes there; the
    # committed full run holds the tight one)
    jpt_gate = 1.25 if d.get("smoke") else 1.05
    assert d["jpt_ratio_paged_vs_contiguous"] <= jpt_gate, \
        d["jpt_ratio_paged_vs_contiguous"]
    # fixed page budget: paging buys real admitted concurrency
    assert d["paged_big"]["kv_cache"]["pages_total"] == w["budget_pages"]
    assert d["paged_big"]["batch_slots"] >= 2 * d["contiguous"]["batch_slots"]
    assert d["concurrency_ratio_fixed_budget"] >= 1.5, \
        d["concurrency_ratio_fixed_budget"]
    # prefix reuse: hits happened, were priced, and cut TTFT
    assert d["prefix_hit_tokens"] > 0
    assert d["saved_prefill_joules"] > 0.0
    assert d["warm_ttft_ratio"] < 1.0, d["warm_ttft_ratio"]
    assert d["prefix_warm"]["kv_cache"]["prefix_hit_tokens"] \
        == d["prefix_hit_tokens"]
    assert d["target_met"] is True, "paged KV gates not met"
    return (f"J/token {d['jpt_ratio_paged_vs_contiguous']:.3f}x contiguous "
            f"at equal batch, {d['concurrency_ratio_fixed_budget']:.2f}x "
            f"concurrency on {w['budget_pages']} pages, "
            f"{d['prefix_hit_tokens']} prefix tokens reused "
            f"({d['saved_prefill_joules']:.1f} J saved, warm TTFT "
            f"{d['warm_ttft_ratio']:.2f}x cold)")


def validate_quant(d):
    w = d["workload"]
    fills = w["fills"]
    gate = w["gate_fills"]
    assert gate and all(f >= w["max_len"] // 2 for f in gate)
    bpt = w["cache_bytes_per_token"]
    for mode in ("int8", "fp8_e4m3"):
        assert bpt[mode] < bpt["bf16"], bpt        # the premise: fewer bytes
    for impl in ("bf16", "int8", "fp8_e4m3"):
        for f in fills:
            _positive_float(d[impl]["fills"][str(f)], "tokens_per_s",
                            "j_per_token", "seconds", "joules",
                            ctx=(impl, f))
            assert d[impl]["fills"][str(f)]["tokens"] > 0
    for mode in ("int8", "fp8_e4m3"):
        dr = d["logit_drift"][mode]
        assert 0.0 <= dr["relative"] < dr["bound"], (mode, dr)
        assert dr["ok"] is True, mode
    assert d["drift_met"] is True
    # perf gates hold on the committed full run; the CI smoke leg is too
    # small to be bandwidth-bound (the bf16 cache fits in LLC), so it
    # validates schema + accuracy only — same relaxation as pmt_paged
    if not d.get("smoke"):
        for f in gate:
            s = d["speedups"]["int8"][str(f)]
            assert s["tokens_per_s"] >= w["tokens_per_s_gate"], (f, s)
            assert s["j_per_token_ratio"] <= w["j_per_token_gate"], (f, s)
        assert d["perf_met"] is True
        assert d["target_met"] is True, "int8 cache did not beat bf16"
    half = d["speedups"]["int8"][str(gate[0])]
    dr8 = d["logit_drift"]["int8"]
    return (f"int8 {half['tokens_per_s']:.2f}x tokens/s, "
            f"{half['j_per_token_ratio']:.2f}x J/token at fill {gate[0]}, "
            f"drift {dr8['relative']:.4f} (bound {dr8['bound']})")


VALIDATORS = {
    "pmt_overhead": validate_overhead,
    "pmt_serve": validate_serve,
    "pmt_decode": validate_decode,
    "pmt_prefill": validate_prefill,
    "pmt_governor": validate_governor,
    "pmt_faults": validate_faults,
    "pmt_paged": validate_paged,
    "pmt_quant": validate_quant,
}


def main(paths):
    if not paths:
        raise SystemExit("usage: validate_bench.py BENCH_x.json [...]")
    for path in paths:
        with open(path) as f:
            d = json.load(f)
        bench = d.get("bench")
        assert bench in VALIDATORS, f"{path}: unknown bench {bench!r}"
        assert isinstance(d["schema_version"], int)
        summary = VALIDATORS[bench](d)
        print(f"{path} schema OK: {summary}")


if __name__ == "__main__":
    main(sys.argv[1:])
