"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep JSONL.

Usage: PYTHONPATH=src python benchmarks/make_experiments_report.py
Prints markdown to stdout (pasted into EXPERIMENTS.md by the build log).
"""
import json
import sys
from collections import OrderedDict

PATH = sys.argv[1] if len(sys.argv) > 1 else \
    "benchmarks/results_dryrun.jsonl"


def load(path):
    rows = OrderedDict()
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"], r["mesh"])] = r  # latest wins
    return rows


def fmt_bytes(b):
    return f"{b / 1e9:.1f}GB" if b >= 1e9 else f"{b / 1e6:.1f}MB"


def dryrun_table(rows, mesh):
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | status | compile_s | args/chip | temp/chip | "
          "total/chip | collectives (full compile, per-chip) |")
    print("|---|---|---|---:|---:|---:|---:|---|")
    for (arch, shape, m), r in rows.items():
        if m != mesh:
            continue
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | ERROR | | | | | "
                  f"{r.get('error','')[:60]} |")
            continue
        mem = r["memory"]
        fc = r.get("full_compile_costs", {})
        kinds = fc.get("coll_by_kind", {})
        coll = ", ".join(f"{k.replace('collective-','c-')}:{fmt_bytes(v)}"
                         for k, v in sorted(kinds.items()) if v > 0) or "—"
        print(f"| {arch} | {shape} | ok | {r['compile_s']:.0f} "
              f"| {mem['argument_gib']:.2f}Gi | {mem['temp_gib']:.2f}Gi "
              f"| {mem['per_chip_gib']:.2f}Gi | {coll} |")


def roofline_table(rows):
    print("\n| arch | shape | FLOPs/chip | HBM B/chip | coll B/chip | "
          "C_s | M_s | X_s | dominant | useful | roofline-frac |")
    print("|---|---|---:|---:|---:|---:|---:|---:|---|---:|---:|")
    for (arch, shape, m), r in rows.items():
        if m != "16x16" or r["status"] != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        print(f"| {arch} | {shape} | {rf['flops_per_chip']:.3e} "
              f"| {rf['hbm_bytes_per_chip']:.3e} "
              f"| {rf['coll_bytes_per_chip']:.3e} "
              f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
              f"| {rf['collective_s']:.4f} | {rf['dominant']} "
              f"| {rf['useful_ratio']:.3f} "
              f"| {rf['roofline_fraction']*100:.1f}% |")


def hillclimb_table(path="benchmarks/results_hillclimb.jsonl"):
    import os
    if not os.path.exists(path):
        return
    print("\n### §Perf iterations (hillclimb)\n")
    print("| experiment | cell | C_s | M_s | X_s | dominant | mem/chip | "
          "roofline-frac |")
    print("|---|---|---:|---:|---:|---|---:|---:|")
    for line in open(path):
        r = json.loads(line)
        if r["status"] != "ok":
            print(f"| {r.get('experiment','?')} | {r['arch']}x{r['shape']} "
                  f"| ERROR {r.get('error','')[:50]} | | | | | |")
            continue
        if "roofline" not in r:   # memory-only experiments (microbatch)
            print(f"| {r.get('experiment','?')} | {r['arch']} x "
                  f"{r['shape']} | | | | (full compile only) "
                  f"| {r['memory']['per_chip_gib']:.2f}Gi | |")
            continue
        rf = r["roofline"]
        print(f"| {r.get('experiment','baseline')} "
              f"| {r['arch']} x {r['shape']} "
              f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
              f"| {rf['collective_s']:.4f} | {rf['dominant']} "
              f"| {r['memory']['per_chip_gib']:.2f}Gi "
              f"| {rf['roofline_fraction']*100:.1f}% |")


if __name__ == "__main__":
    rows = load(PATH)
    n_ok = sum(1 for r in rows.values() if r["status"] == "ok")
    print(f"<!-- generated from {PATH}: {len(rows)} cells, {n_ok} ok -->")
    print("\n## §Dry-run")
    dryrun_table(rows, "16x16")
    dryrun_table(rows, "2x16x16")
    print("\n## §Roofline (single-pod, 256 chips)")
    roofline_table(rows)
    hillclimb_table()
