"""Power-capped serving A/B — uncapped vs governed under a watts budget.

The serve engine measures per-request J/token; the ``PowerGovernor``
closes the loop: it reads smoothed window power from a
``PowerRecorder`` and holds the engine under a watts cap by gating
admission, pacing prefill chunks, and (last resort) duty-cycling
decode.  This benchmark drives the whole control loop on the dummy
backend with a **load-coupled power model**: the sensor's waveform
reads the engine's live ``live_slots`` gauge —

    watts(t) = idle_w + slot_w * engine.live_slots

— so power genuinely responds to scheduling decisions, the thing a
constant waveform cannot do.  Full batch draws
``idle_w + slot_w * batch`` watts; the cap is set between the 2-slot
and 3-slot levels, so holding it *requires* the governor to keep
concurrency at 2.

Three runs of the same workload through the same engine:
  * ``baseline``  — no governor attached;
  * ``uncapped``  — governor attached with ``cap_watts=None`` (pure
    observer: measures control-plane overhead);
  * ``capped``    — governor with the cap.

Pass criteria (written into BENCH_governor.json, validated by CI via
benchmarks/validate_bench.py):
  * cap held: every sliding-window mean (governor window) after the
    ramp-in stays ``<= cap * 1.05``, while uncapped power demonstrably
    exceeds the cap (else the cap constrained nothing);
  * liveness: the capped run completes every request in full
    (tokens == baseline tokens — throttling defers work, never drops
    it) and tokens/s degrades gracefully (>= 0.25x baseline, not a
    collapse);
  * no observer overhead: uncapped-governed J/token within 15% of
    baseline;
  * the governor actually acted: >= 1 throttle decision in the capped
    run, 0 in the uncapped run.

Usage: PYTHONPATH=src python benchmarks/bench_governor.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.core.backends.dummy import DummySensor
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine
from repro.serve.governor import PowerGovernor
from repro.telemetry import PowerRecorder

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_governor.json")

IDLE_W = 50.0
SLOT_W = 15.0


def make_workload(n_requests: int, plen_lo: int, plen_hi: int,
                  max_new_lo: int, max_new_hi: int, vocab: int,
                  seed: int = 0):
    """Decode-heavy mix — long generations give the governor a long
    steady-state window to hold the cap over."""
    rng = np.random.default_rng(seed)
    return [Request(
        prompt=rng.integers(0, vocab,
                            size=int(rng.integers(plen_lo,
                                                  plen_hi + 1))).tolist(),
        max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1)))
        for _ in range(n_requests)]


def window_max_watts(series, window_s: float, ramp_s: float):
    """Max sliding-window mean over the post-ramp tail of a
    ``[[t, w], ...]`` power series (the cap-hold metric: the governor
    promises *smoothed* power under the cap, not every raw sample)."""
    if not series:
        return 0.0
    # Skip the ramp-in, but never the whole series (a short smoke run
    # must still yield a peak): fall back to the trailing half.
    t_start = min(series[0][0] + ramp_s,
                  series[0][0] + 0.5 * (series[-1][0] - series[0][0]))
    worst = 0.0
    for i, (t_i, _w) in enumerate(series):
        if t_i < t_start:
            continue
        win = [w for t, w in series[max(0, i - 512):i + 1]
               if t >= t_i - window_s]
        if win:
            worst = max(worst, sum(win) / len(win))
    return worst


def run_mode(cfg, params, workload, mode: str, cap: float, batch: int,
             max_len: int, chunk: int, window_s: float):
    """One serve run on a private session whose dummy sensor's power
    tracks the engine's live-slot gauge."""
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      session=None, prefill_chunk=chunk,
                      cache_dtype=jnp.float32)
    eng.generate([Request(prompt=[1] * (chunk + 1), max_new_tokens=2)])

    # Load-coupled model: the waveform closure reads the engine gauge at
    # sampler-tick time, so admissions/retirements show up in the power
    # trace within one sampling period.
    sensor = DummySensor(watts_fn=lambda t: IDLE_W + SLOT_W * eng.live_slots)
    with pmt.Session([sensor], pool=pmt.SensorPool(),
                     period_s=0.002) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        with PowerRecorder(poll_period_s=0.01).attach(
                sess, exporter=mem) as rec:
            gov = None
            if mode != "baseline":
                gov = PowerGovernor(
                    rec, cap_watts=(cap if mode == "capped" else None),
                    window_s=window_s)
            eng.session = sess
            eng.governor = gov
            reqs = [dataclasses.replace(r) for r in workload]
            t0 = time.perf_counter()
            done = eng.generate(reqs)
            seconds = time.perf_counter() - t0
            eng.session = None
            eng.governor = None
            sess.flush()
            rec.poll_once()      # final sampler tail into the timeline

            tokens = sum(len(r.out) for r in done)
            complete = all(len(r.out) == r.max_new_tokens for r in done)
            series = rec.watts_series("dummy").get("dummy", [])
            agg = [r for r in mem.records
                   if r.path.startswith("serve/batch")]
            gov_stats = gov.stats() if gov is not None else None
            if gov is not None:
                gov.close()
    joules = sum(r.joules for r in agg)
    return {
        "mode": mode,
        "cap_watts": cap if mode == "capped" else None,
        "seconds": seconds,
        "tokens": tokens,
        "all_requests_complete": bool(complete),
        "tokens_per_s": tokens / max(seconds, 1e-9),
        "joules": joules,
        "j_per_token": joules / max(tokens, 1),
        "watts_samples": len(series),
        "peak_window_watts": window_max_watts(series, window_s,
                                              ramp_s=2 * window_s),
        "governor": gov_stats,
    }


def main(smoke=False, json_out=DEFAULT_JSON):
    # Bench-scaled config (see bench_prefill.py for the sizing
    # rationale); decode-heavy workload so the run spends most of its
    # wall clock in the steady state the cap-hold gate inspects.
    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
        vocab_size=1024, attn_chunk=128)
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    chunk = 32
    batch = 4
    window_s = 0.1
    n_requests = 4 if smoke else 8
    plen_lo, plen_hi = 33, 64
    max_new_lo, max_new_hi = (16, 24) if smoke else (24, 48)
    # Cap between the 2-slot (80 W) and 3-slot (95 W) load levels:
    # holding it forces concurrency 2, full batch would draw 110 W.
    cap = IDLE_W + 2.5 * SLOT_W
    workload = make_workload(n_requests, plen_lo, plen_hi, max_new_lo,
                             max_new_hi, cfg.vocab_size)
    padded_hi = -(-plen_hi // chunk) * chunk
    max_len = padded_hi + max_new_hi

    runs = {m: run_mode(cfg, params, workload, m, cap, batch, max_len,
                        chunk, window_s)
            for m in ("baseline", "uncapped", "capped")}
    baseline, uncapped, capped = (runs[m] for m in
                                  ("baseline", "uncapped", "capped"))

    cap_tol = cap * 1.05
    cap_held = capped["peak_window_watts"] <= cap_tol
    cap_binding = uncapped["peak_window_watts"] > cap_tol
    liveness = (capped["all_requests_complete"]
                and capped["tokens"] == baseline["tokens"]
                and capped["tokens_per_s"]
                >= 0.25 * baseline["tokens_per_s"])
    overhead_ok = uncapped["j_per_token"] \
        <= 1.15 * baseline["j_per_token"]
    acted = (capped["governor"]["throttle_decisions"] >= 1
             and uncapped["governor"]["throttle_decisions"] == 0)
    target_met = bool(cap_held and cap_binding and liveness
                      and overhead_ok and acted)

    print("# power-capped serving A/B (load-coupled dummy: "
          f"{IDLE_W:.0f} W idle + {SLOT_W:.0f} W/slot, cap {cap:.0f} W)")
    print(f"{'mode':10s} {'tok/s':>8s} {'J/token':>9s} {'seconds':>8s} "
          f"{'peakW(win)':>11s} {'throttles':>9s}")
    for d in runs.values():
        g = d["governor"]
        print(f"{d['mode']:10s} {d['tokens_per_s']:8.1f} "
              f"{d['j_per_token']:9.4f} {d['seconds']:8.3f} "
              f"{d['peak_window_watts']:11.1f} "
              f"{g['throttle_decisions'] if g else '-':>9}")
    print(f"# cap held: peak window {capped['peak_window_watts']:.1f} W "
          f"<= {cap_tol:.1f} W ({'PASS' if cap_held else 'FAIL'}); "
          f"binding: uncapped peak {uncapped['peak_window_watts']:.1f} W "
          f"({'yes' if cap_binding else 'NO'})")
    print(f"# liveness: complete={capped['all_requests_complete']} "
          f"tokens {capped['tokens']}/{baseline['tokens']}, "
          f"{capped['tokens_per_s'] / max(baseline['tokens_per_s'], 1e-9):.2f}x "
          f"baseline tokens/s ({'PASS' if liveness else 'FAIL'}); "
          f"observer overhead "
          f"{uncapped['j_per_token'] / max(baseline['j_per_token'], 1e-12):.3f}x "
          f"J/token ({'OK' if overhead_ok else 'FAIL'})")
    print(f"# capped-run throttle actions: "
          f"{capped['governor']['throttle_actions']} "
          f"({'PASS' if acted else 'FAIL'}); overall "
          f"{'PASS' if target_met else 'FAIL'}")

    if json_out:
        payload = {
            "bench": "pmt_governor",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "arch": "smollm-135m (bench-scaled reduced cfg: 4L/d256, "
                        "fp32)",
                "backend": "dummy (load-coupled: idle + per-slot watts)",
                "idle_watts": IDLE_W,
                "slot_watts": SLOT_W,
                "cap_watts": cap,
                "window_s": window_s,
                "n_requests": n_requests,
                "batch": batch,
                "max_len": max_len,
                "prefill_chunk": chunk,
                "prompt_lengths": [plen_lo, plen_hi],
                "max_new_tokens": [max_new_lo, max_new_hi],
            },
            "baseline": baseline,
            "uncapped": uncapped,
            "capped": capped,
            "cap_held": bool(cap_held),
            "cap_binding": bool(cap_binding),
            "liveness_ok": bool(liveness),
            "observer_overhead_ok": bool(overhead_ok),
            "governor_acted": bool(acted),
            "target_met": target_met,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return target_met


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer/shorter requests)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_governor.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
