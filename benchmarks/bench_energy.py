"""Paper §III — derived energy-efficiency metrics: EDP and GFLOP/s/W.

The paper computes GFLOP/s/W from externally counted FLOPs (PAPI/LIKWID);
our FLOP source is XLA ``cost_analysis()`` of the measured region itself.
Benchmarks a GEMM sweep and reports J, EDP, GFLOP/s/W per size from the
modeled TPU sensor, plus J/token for one reduced-model train step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.core as pmt
from repro.core.backends.tpu import TpuCostModelSensor
from repro.core.metrics import EfficiencyReport


def main(csv=False):
    rows = []
    key = jax.random.PRNGKey(0)
    for n in (256, 512, 1024):
        a = jax.random.normal(key, (n, n), jnp.bfloat16)
        b = jax.random.normal(key, (n, n), jnp.bfloat16)
        f = jax.jit(lambda x, y: x @ y)
        compiled = f.lower(a, b).compile()
        flops = float(compiled.cost_analysis().get("flops", 2 * n ** 3))

        sensor = TpuCostModelSensor.create()
        s0 = sensor.read()
        t0 = time.perf_counter()
        out = f(a, b)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        sensor.account(flops=flops, hbm_bytes=3 * n * n * 2, ici_bytes=0.0,
                       seconds=dt)
        s1 = sensor.read()
        rep = EfficiencyReport(joules=pmt.joules(s0, s1), seconds=dt,
                               flops=flops)
        rows.append((f"gemm_{n}", rep))

    # one train step of the reduced example model, J/token
    from repro import configs
    from repro.optim.optimizers import OptimizerConfig
    from repro.train.steps import init_train_state, make_train_step
    cfg = configs.get_config("smollm-135m", reduced=True)
    ocfg = OptimizerConfig()
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = {"tokens": jnp.ones((4, 64), jnp.int32),
             "targets": jnp.ones((4, 64), jnp.int32)}
    mon = pmt.PowerMonitor(["cpuutil", "tpu"])
    state, m = step(state, batch)          # compile outside measurement
    jax.block_until_ready(m["loss"])
    with mon.measure_step(0, tokens=4 * 64) as box:
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
    recs = box.records

    print("# Energy-efficiency metrics (paper §III): EDP, GFLOP/s/W")
    print(f"{'case':12s} {'J':>10s} {'s':>9s} {'EDP(Js)':>11s} "
          f"{'GFLOP/s/W':>10s}")
    for name, rep in rows:
        g = rep.gflops_per_watt or 0.0
        print(f"{name:12s} {rep.joules:10.4f} {rep.seconds:9.4f} "
              f"{rep.edp:11.5f} {g:10.3f}")
    for r in recs:
        jt = r.joules / max(1, r.tokens or 1)
        print(f"train_step[{r.sensor}:{r.kind}]  J={r.joules:.4f}  "
              f"J/token={jt:.6f}")
    if csv:
        for name, rep in rows:
            print(f"energy_{name},{rep.seconds*1e6:.1f},"
                  f"edp={rep.edp:.5f};gflops_per_w="
                  f"{rep.gflops_per_watt or 0:.3f}")
    return rows


if __name__ == "__main__":
    main()
