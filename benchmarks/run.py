"""Benchmark orchestrator — one section per paper table/figure.

  fig2      kernel power profiles (paper Fig. 2)
  overhead  instrumentation overhead (paper §II, ~1 ms / ~10 ms claims)
  sampling  dump-mode sampling rates (paper §II, NVML 10 ms / RAPL 500 ms)
  energy    EDP + GFLOP/s/W derived metrics (paper §III)
  roofline  dry-run roofline table, if results_dryrun.jsonl exists

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
"""
from __future__ import annotations

import json
import os
import sys
import time


def _section(title):
    print("\n" + "=" * 72)
    print(f"== {title}")
    print("=" * 72, flush=True)


def roofline_table(path="benchmarks/results_dryrun.jsonl"):
    if not os.path.exists(path):
        print(f"(no {path} — run `python -m repro.launch.dryrun --all`)")
        return
    rows = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    print(f"{'arch':18s} {'shape':12s} {'mesh':8s} {'status':7s} "
          f"{'mem/chip':>9s} {'dom':>10s} {'C_s':>9s} {'M_s':>9s} "
          f"{'X_s':>9s} {'roofline%':>9s}")
    for (arch, shape, mesh), r in sorted(rows.items()):
        if r["status"] != "ok":
            print(f"{arch:18s} {shape:12s} {mesh:8s} ERROR   "
                  f"{r.get('error', '')[:60]}")
            continue
        mem = r["memory"]["per_chip_gib"]
        rf = r.get("roofline")
        if rf:
            print(f"{arch:18s} {shape:12s} {mesh:8s} ok      "
                  f"{mem:8.2f}G {rf['dominant']:>10s} "
                  f"{rf['compute_s']:9.4f} {rf['memory_s']:9.4f} "
                  f"{rf['collective_s']:9.4f} "
                  f"{100*rf['roofline_fraction']:8.1f}%")
        else:
            print(f"{arch:18s} {shape:12s} {mesh:8s} ok      "
                  f"{mem:8.2f}G {'(full only)':>10s}")


def main() -> None:
    sections = sys.argv[1:] or ["fig2", "overhead", "sampling", "energy",
                                "roofline"]
    t0 = time.time()
    if "fig2" in sections:
        _section("Paper Fig. 2 — kernel power profiles (PMT stacked)")
        from benchmarks.bench_fig2_kernels import main as fig2
        fig2(csv=True)
    if "overhead" in sections:
        _section("Paper §II — instrumentation overhead")
        from benchmarks.bench_overhead import main as overhead
        overhead(csv=True)
    if "sampling" in sections:
        _section("Paper §II — dump-mode sampling rates")
        from benchmarks.bench_sampling import main as sampling
        sampling(csv=True)
    if "energy" in sections:
        _section("Paper §III — EDP / GFLOP/s/W")
        from benchmarks.bench_energy import main as energy
        energy(csv=True)
    if "roofline" in sections:
        _section("Dry-run roofline table (EXPERIMENTS.md §Roofline)")
        roofline_table()
    print(f"\n# benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
