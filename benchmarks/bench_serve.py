"""Serving A/B — synchronized waves vs sequence-level continuous batching.

The paper's efficiency metric applied to serving is J/token; the wave
engine decodes ``max(max_new_tokens)`` steps for every request in a
wave, so short requests idle (and burn joules) behind the longest one.
This benchmark runs the *same heterogeneous-length workload* — mixed
prompt lengths, strongly mixed generation lengths — through both engine
modes on the dummy backend (constant watts, so joules track wall time
deterministically) and reports tokens/s and J/token per mode, plus
per-request spans from the continuous engine.

Pass criteria (written into BENCH_serve.json, validated by CI):
  * continuous >= wave on tokens/s AND <= wave on J/token;
  * per-request span token counts sum to the aggregate region's tokens;
  * decode compiles once; prefill compiles <= number of prompt buckets.

Usage: PYTHONPATH=src python benchmarks/bench_serve.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

import repro.core as pmt
from repro import configs
from repro.models import model as model_mod
from repro.serve.engine import Request, ServeEngine, prompt_bucket

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_serve.json")


def make_workload(n_requests: int, short_new: int, long_new: int,
                  vocab: int, max_plen: int, seed: int = 0):
    """Heterogeneous mix: varied prompt lengths, alternating short/long
    generation — the case wave synchronization is worst at."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(2, max_plen + 1))
        max_new = short_new if i % 2 == 0 else long_new
        reqs.append(Request(
            prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=max_new))
    return reqs


def run_mode(cfg, params, workload, mode: str, batch: int, max_len: int,
             repeats: int = 1):
    """Best-of-``repeats`` engine run on a private dummy-backend session.

    The engine is warmed (one tiny request per prompt bucket) *before*
    the session attaches and the clock starts, so both modes measure
    steady-state serving, not jit compilation.  "Best" = fastest wall
    clock; its measured spans are the ones reported (dummy watts are
    constant, so joules track the same run).
    """
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      session=None, mode=mode)
    for bucket in sorted({prompt_bucket(len(r.prompt)) for r in workload}):
        eng.generate([Request(prompt=[1] * bucket, max_new_tokens=2)])
    best = None
    for _ in range(repeats):
        with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
            mem = sess.add_exporter(pmt.MemoryExporter())
            eng.session = sess
            reqs = [dataclasses.replace(r) for r in workload]
            t0 = time.perf_counter()
            done = eng.generate(reqs)
            seconds = time.perf_counter() - t0
            eng.session = None
            sess.flush()
            if best is not None and seconds >= best["seconds"]:
                continue
            tokens = sum(len(r.out) for r in done)
            agg = [r for r in mem.records
                   if r.path.startswith(("serve/batch", "serve/wave"))]
            # whole-request spans only — each request also carries
            # serve/req<N>/{prefill,decode} phase child scopes
            per_req = [r for r in mem.records
                       if r.path.startswith("serve/req")
                       and "/" not in r.path.replace("serve/", "")]
            joules = sum(r.joules for r in agg)
            best = {
                "mode": mode,
                "seconds": seconds,
                "tokens": tokens,
                "tokens_per_s": tokens / max(seconds, 1e-9),
                "joules": joules,
                "j_per_token": joules / max(tokens, 1),
                "aggregate_region_tokens": int(sum(r.tokens for r in agg)),
                "compile_counts": dict(eng.compile_counts),
            }
            if mode == "continuous":
                best["per_request"] = sorted(
                    ({"path": r.path, "tokens": r.tokens,
                      "joules": r.joules,
                      "j_per_token": r.joules / max(r.tokens, 1)}
                     for r in per_req), key=lambda d: d["path"])
                best["request_token_sum"] = int(
                    sum(r.tokens for r in per_req))
    return best


def main(smoke=False, json_out=DEFAULT_JSON):
    # Bench-local config: big enough that a decode step is compute-bound
    # (~20 ms on CPU), so the A/B measures scheduling policy rather than
    # per-dispatch runtime overhead.  The smoke variant keeps the same
    # shape at a single prompt bucket and shorter generations.
    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
        vocab_size=1024, attn_chunk=128)
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    batch, max_len = (4, 64) if smoke else (8, 128)
    n_requests = 16 if smoke else 24
    short_new, long_new = (2, 24) if smoke else (4, 48)
    max_plen = 8 if smoke else 20      # smoke: one bucket, minimal compiles
    repeats = 1 if smoke else 2
    workload = make_workload(n_requests, short_new, long_new,
                             cfg.vocab_size, max_plen)
    buckets = {prompt_bucket(len(r.prompt)) for r in workload}

    # wave first so continuous cannot ride its jit warm-up; each mode
    # runs on a fresh engine (fresh jit caches) anyway.
    wave = run_mode(cfg, params, workload, "wave", batch, max_len, repeats)
    cont = run_mode(cfg, params, workload, "continuous", batch, max_len,
                    repeats)

    speedup = cont["tokens_per_s"] / max(wave["tokens_per_s"], 1e-9)
    jpt_ratio = wave["j_per_token"] / max(cont["j_per_token"], 1e-12)
    token_sum_ok = cont["request_token_sum"] == cont["tokens"] \
        == cont["aggregate_region_tokens"]
    target_met = bool(speedup >= 1.0 and jpt_ratio >= 1.0 and token_sum_ok)

    print("# serve A/B: synchronized waves vs continuous batching")
    print(f"{'mode':12s} {'tok/s':>10s} {'J/token':>10s} {'seconds':>9s} "
          f"{'tokens':>7s} {'compiles(p/d)':>14s}")
    for d in (wave, cont):
        cc = d["compile_counts"]
        print(f"{d['mode']:12s} {d['tokens_per_s']:10.1f} "
              f"{d['j_per_token']:10.4f} {d['seconds']:9.3f} "
              f"{d['tokens']:7d} {cc['prefill']:>8d}/{cc['decode']}")
    print(f"# continuous vs wave: {speedup:.2f}x tokens/s, "
          f"{jpt_ratio:.2f}x lower J/token "
          f"({'PASS' if target_met else 'FAIL'})")
    print(f"# per-request token sum {cont['request_token_sum']} vs "
          f"aggregate {cont['tokens']}: "
          f"{'OK' if token_sum_ok else 'MISMATCH'}")
    print(f"# prompt buckets {sorted(buckets)}; continuous decode "
          f"compiled {cont['compile_counts']['decode']}x, prefill "
          f"{cont['compile_counts']['prefill']}x "
          f"(<= {len(buckets)} buckets)")

    if json_out:
        payload = {
            "bench": "pmt_serve",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "arch": "smollm-135m (bench-scaled reduced cfg: 4L/d256, "
                        "fp32)",
                "backend": "dummy",
                "n_requests": n_requests,
                "batch": batch,
                "max_len": max_len,
                "gen_lengths": [short_new, long_new],
                "prompt_buckets": sorted(buckets),
            },
            "wave": wave,
            "continuous": cont,
            "speedup_tokens_per_s": speedup,
            "jpt_improvement": jpt_ratio,
            "request_token_sum_matches": token_sum_ok,
            "decode_compiles_once":
                cont["compile_counts"]["decode"] == 1,
            "target_met": target_met,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return target_met


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer/shorter requests)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_serve.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
