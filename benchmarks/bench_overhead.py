"""Paper overhead claim — ~1 ms (C++) / ~10 ms (Python) per measurement,
cumulative when decorators stack — plus the array-core A/B.

Measured here:

  (a) raw read()-pair cost per backend (the C++-API analogue);
  (b) decorator overhead on a no-op function for 1..3 stacked
      decorators (linear growth, inside the paper's Python envelope);
  (c) blocking ``@measure`` vs ``session.region`` (the PR-1 claim);
  (d) the zero-allocation core A/B — per-region close overhead across
      three modes on the dummy backend:

        list_core_sync    PMT_LEGACY_RING=1 list-of-State ring, each
                          region resolved synchronously on close
                          (bisect + scalar lerp + one closing sample) —
                          the previous revision's session path;
        array_core_sync   NumPy ring + seqlock, still resolving each
                          region synchronously on close;
        array_core_async  NumPy ring, O(1) close (clock reads + span
                          enqueue); resolution happens in vectorized
                          batches on the background resolver thread.

      Target: async close >= 5x cheaper than the list-core sync path;

  (e) sampler tick jitter (achieved inter-sample period) for both cores.

Results land in ``BENCH_overhead.json`` at the repo root (schema below),
seeding the perf trajectory; CI runs ``--smoke`` and validates the
schema.  Batch-resolution throughput comes from bench_resolve.py and is
merged into the same file.

Usage: PYTHONPATH=src python benchmarks/bench_overhead.py \
           [--smoke] [--csv] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import repro.core as pmt

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_overhead.json")


def _time_per_call(fn, n=200, repeats=5):
    """Best-of-``repeats`` mean over ``n`` calls (min filters scheduler
    noise — the background sampler and the container's neighbours both
    add tail jitter that is not the API's own overhead)."""
    fn()  # warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, (time.perf_counter() - t0) / n)
    return best


# ---------------------------------------------------------------------------
# (d) the three-mode region-close A/B
# ---------------------------------------------------------------------------

def _bench_region_mode(legacy: bool, resolve_inline: bool,
                       n: int, repeats: int) -> float:
    """us per region cycle on a private pool/session."""
    env_before = os.environ.get("PMT_LEGACY_RING")
    os.environ["PMT_LEGACY_RING"] = "1" if legacy else "0"
    try:
        with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
            if resolve_inline:
                def cycle():
                    with sess.region("bench") as r:
                        pass
                    r.measurements          # synchronous resolve on close
            else:
                def cycle():
                    with sess.region("bench"):
                        pass                # O(1) close; resolver catches up
            us = _time_per_call(cycle, n=n, repeats=repeats) * 1e6
            sess.flush()                    # settle before teardown timing
        return us
    finally:
        if env_before is None:
            os.environ.pop("PMT_LEGACY_RING", None)
        else:
            os.environ["PMT_LEGACY_RING"] = env_before


def bench_region_modes(smoke: bool = False) -> dict:
    n = 300 if smoke else 2000
    repeats = 3 if smoke else 9
    modes = {
        "list_core_sync": _bench_region_mode(True, True, n, repeats),
        "array_core_sync": _bench_region_mode(False, True, n, repeats),
        "array_core_async": _bench_region_mode(False, False, n, repeats),
    }
    return {k: {"region_close_us": v} for k, v in modes.items()}


# ---------------------------------------------------------------------------
# (e) sampler tick jitter
# ---------------------------------------------------------------------------

def bench_tick_jitter(smoke: bool = False) -> dict:
    """Achieved inter-sample period stats per core at a 1 ms request."""
    duration = 0.25 if smoke else 1.0
    out = {}
    for name, legacy in (("array_core", False), ("list_core", True)):
        sensor = pmt.create("dummy", watts=42.0)
        env_before = os.environ.get("PMT_LEGACY_RING")
        os.environ["PMT_LEGACY_RING"] = "1" if legacy else "0"
        try:
            sampler = pmt.make_ring_sampler(sensor, period_s=0.001)
        finally:
            if env_before is None:
                os.environ.pop("PMT_LEGACY_RING", None)
            else:
                os.environ["PMT_LEGACY_RING"] = env_before
        with sampler:
            time.sleep(duration)
        if legacy:
            ts = np.array([s.timestamp_s for s in sampler.snapshot()])
        else:
            ts, _, _ = sampler.timeline()
        dt = np.diff(ts) * 1e6
        dt = dt[dt > 0]                     # drop the stop()-tick double
        out[name] = {
            "period_request_us": 1000.0,
            "samples": int(ts.size),
            "median_dt_us": float(np.median(dt)) if dt.size else 0.0,
            "p99_dt_us": float(np.percentile(dt, 99)) if dt.size else 0.0,
            "std_dt_us": float(np.std(dt)) if dt.size else 0.0,
        }
    return out


# ---------------------------------------------------------------------------
# (a)-(c) the paper-envelope cases (kept from the previous revisions)
# ---------------------------------------------------------------------------

def bench_session_vs_blocking(rows, n=2000):
    """Hot-path comparison on the dummy backend.

    Blocking mode: the classic ``@pmt.measure`` wrapper — two synchronous
    ``Sensor.read()`` calls (lock, sample, trapezoid integration, State)
    bracketing every call.  Session mode: ``session.region`` enter/exit —
    sensor-clock timestamps plus a span enqueue; joules resolve later
    against the shared ring buffer, off the measured path.
    """
    blocking = pmt.measure("dummy")(lambda: None)
    us_blocking = _time_per_call(blocking, n=n, repeats=9) * 1e6

    with pmt.Session(["dummy"]) as sess:
        def region_call():
            with sess.region("bench"):
                pass

        us_session = _time_per_call(region_call, n=n, repeats=9) * 1e6
        # Resolution stays correct even though it's off the hot path:
        # constant-watts dummy over a real sleep must yield positive J.
        with sess.region("check") as r:
            time.sleep(0.002)
        assert r.measurements[0].joules > 0.0

    rows.append(("measure_blocking", us_blocking))
    rows.append(("session_region", us_session))
    return us_blocking / max(us_session, 1e-9)


def main(csv=False, smoke=False, json_out=DEFAULT_JSON):
    rows = []
    for backend in ("dummy", "cpuutil", "tpu"):
        s = pmt.create(backend)

        def pair(s=s):
            a = s.read()
            b = s.read()
            return a, b

        us = _time_per_call(pair) * 1e6
        rows.append((f"read_pair_{backend}", us))

    for stack in (1, 2, 3):
        fn = lambda: None
        for _ in range(stack):
            fn = pmt.measure("dummy")(fn)
        us = _time_per_call(fn, n=100) * 1e6
        rows.append((f"decorator_x{stack}", us))

    session_ratio = bench_session_vs_blocking(rows)
    modes = bench_region_modes(smoke=smoke)
    jitter = bench_tick_jitter(smoke=smoke)
    try:                                    # script- or package-style run
        from benchmarks.bench_resolve import measure_resolve_throughput
    except ImportError:
        from bench_resolve import measure_resolve_throughput
    resolve = measure_resolve_throughput(
        timeline_n=20_000 if smoke else 100_000,
        spans_m=512 if smoke else 4096,
        repeats=3 if smoke else 5)

    print("# PMT overhead (paper: ~1 ms C++ / ~10 ms Python per region)")
    print(f"{'case':22s} {'us/call':>10s} {'paper budget':>14s}")
    budget = {"read_pair": 1_000.0, "decorator": 10_000.0}
    ok = True
    for name, us in rows:
        b = budget["read_pair" if name.startswith("read") else "decorator"]
        mult = int(name[-1]) if name.startswith("decorator") else 1
        within = us <= b * mult
        ok &= within
        print(f"{name:22s} {us:10.1f} {'<= ' + str(int(b * mult)):>14s}"
              f" {'OK' if within else 'OVER'}")
    print(f"# overall: {'PASS' if ok else 'FAIL'} vs paper envelope")
    # PR-1's 2x target predates span pinning + the bounded async queue;
    # the close now does strictly more (eviction detection, resolver
    # hand-off), so the decorator-vs-region floor is 1.25x and the real
    # hot-path gate is the 5x async-vs-list-core A/B below.
    print(f"# session.region vs blocking @measure: {session_ratio:.1f}x "
          f"lower per-region overhead "
          f"({'PASS' if session_ratio >= 1.25 else 'FAIL'} vs 1.25x floor)")

    us_list = modes["list_core_sync"]["region_close_us"]
    us_sync = modes["array_core_sync"]["region_close_us"]
    us_async = modes["array_core_async"]["region_close_us"]
    speedup_async = us_list / max(us_async, 1e-9)
    speedup_sync = us_list / max(us_sync, 1e-9)
    print("# array-core A/B (per-region close, dummy backend)")
    for mode, d in modes.items():
        print(f"{mode:22s} {d['region_close_us']:10.2f} us/region")
    print(f"# async vs list-core: {speedup_async:.1f}x lower "
          f"({'PASS' if speedup_async >= 5.0 else 'FAIL'} vs 5x target); "
          f"sync vs list-core: {speedup_sync:.1f}x")
    for core, j in jitter.items():
        print(f"# tick jitter [{core}]: median {j['median_dt_us']:.0f} us, "
              f"p99 {j['p99_dt_us']:.0f} us over {j['samples']} samples")
    print(f"# batch resolve: {resolve['vectorized_spans_per_s']:.0f} "
          f"spans/s vectorized vs {resolve['scalar_spans_per_s']:.0f} "
          f"scalar ({resolve['speedup']:.1f}x)")

    if csv:
        for name, us in rows:
            print(f"overhead_{name},{us:.2f},paper_env_ok={ok}")
        print(f"overhead_session_speedup,{session_ratio:.2f},"
              f"floor_ok={session_ratio >= 1.25}")
        print(f"overhead_async_core_speedup,{speedup_async:.2f},"
              f"target_5x_ok={speedup_async >= 5.0}")

    if json_out:
        payload = {
            "bench": "pmt_overhead",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "modes": modes,
            "speedup_async_vs_list_core": speedup_async,
            "speedup_sync_vs_list_core": speedup_sync,
            "target_async_speedup": 5.0,
            "target_met": bool(speedup_async >= 5.0),
            "tick_jitter": jitter,
            "resolve_throughput": resolve,
            "session_vs_blocking_speedup": session_ratio,
            "paper_envelope_ok": bool(ok),
            "cases_us": {name: us for name, us in rows},
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer iterations)")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_overhead.json ('' disables)")
    a = ap.parse_args()
    main(csv=a.csv, smoke=a.smoke, json_out=a.json_out)
