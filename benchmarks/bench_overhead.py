"""Paper overhead claim — ~1 ms (C++) / ~10 ms (Python) per measurement,
cumulative when decorators stack.

We measure (a) the raw read()-pair cost per backend (the C++-API
analogue), (b) the decorator overhead on a no-op function for 1..3
stacked decorators, verifying overhead grows ~linearly with stacking and
stays inside the paper's Python envelope.
"""
from __future__ import annotations

import time

import repro.core as pmt


def _time_per_call(fn, n=200):
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def main(csv=False):
    rows = []
    for backend in ("dummy", "cpuutil", "tpu"):
        s = pmt.create(backend)

        def pair(s=s):
            a = s.read()
            b = s.read()
            return a, b

        us = _time_per_call(pair) * 1e6
        rows.append((f"read_pair_{backend}", us))

    for stack in (1, 2, 3):
        fn = lambda: None
        for _ in range(stack):
            fn = pmt.measure("dummy")(fn)
        us = _time_per_call(fn, n=100) * 1e6
        rows.append((f"decorator_x{stack}", us))

    print("# PMT overhead (paper: ~1 ms C++ / ~10 ms Python per region)")
    print(f"{'case':22s} {'us/call':>10s} {'paper budget':>14s}")
    budget = {"read_pair": 1_000.0, "decorator": 10_000.0}
    ok = True
    for name, us in rows:
        b = budget["read_pair" if name.startswith("read") else "decorator"]
        mult = int(name[-1]) if name.startswith("decorator") else 1
        within = us <= b * mult
        ok &= within
        print(f"{name:22s} {us:10.1f} {'<= ' + str(int(b * mult)):>14s}"
              f" {'OK' if within else 'OVER'}")
    print(f"# overall: {'PASS' if ok else 'FAIL'} vs paper envelope")
    if csv:
        for name, us in rows:
            print(f"overhead_{name},{us:.2f},paper_env_ok={ok}")
    return rows


if __name__ == "__main__":
    main()
