"""Paper overhead claim — ~1 ms (C++) / ~10 ms (Python) per measurement,
cumulative when decorators stack.

We measure (a) the raw read()-pair cost per backend (the C++-API
analogue), (b) the decorator overhead on a no-op function for 1..3
stacked decorators, verifying overhead grows ~linearly with stacking and
stays inside the paper's Python envelope, and (c) blocking ``@measure``
vs ``session.region`` on the same dummy backend — the Session redesign's
hot-path claim: region entry/exit is clock reads + a span append, with
resolution deferred to the shared ring sampler, so per-region overhead
must come in at least 2x below the blocking decorator.
"""
from __future__ import annotations

import time

import repro.core as pmt


def _time_per_call(fn, n=200, repeats=5):
    """Best-of-``repeats`` mean over ``n`` calls (min filters scheduler
    noise — the background sampler and the container's neighbours both
    add tail jitter that is not the API's own overhead)."""
    fn()  # warm up
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, (time.perf_counter() - t0) / n)
    return best


def main(csv=False):
    rows = []
    for backend in ("dummy", "cpuutil", "tpu"):
        s = pmt.create(backend)

        def pair(s=s):
            a = s.read()
            b = s.read()
            return a, b

        us = _time_per_call(pair) * 1e6
        rows.append((f"read_pair_{backend}", us))

    for stack in (1, 2, 3):
        fn = lambda: None
        for _ in range(stack):
            fn = pmt.measure("dummy")(fn)
        us = _time_per_call(fn, n=100) * 1e6
        rows.append((f"decorator_x{stack}", us))

    session_ratio = bench_session_vs_blocking(rows)

    print("# PMT overhead (paper: ~1 ms C++ / ~10 ms Python per region)")
    print(f"{'case':22s} {'us/call':>10s} {'paper budget':>14s}")
    budget = {"read_pair": 1_000.0, "decorator": 10_000.0}
    ok = True
    for name, us in rows:
        b = budget["read_pair" if name.startswith("read") else "decorator"]
        mult = int(name[-1]) if name.startswith("decorator") else 1
        within = us <= b * mult
        ok &= within
        print(f"{name:22s} {us:10.1f} {'<= ' + str(int(b * mult)):>14s}"
              f" {'OK' if within else 'OVER'}")
    print(f"# overall: {'PASS' if ok else 'FAIL'} vs paper envelope")
    print(f"# session.region vs blocking @measure: {session_ratio:.1f}x "
          f"lower per-region overhead "
          f"({'PASS' if session_ratio >= 2.0 else 'FAIL'} vs 2x target)")
    if csv:
        for name, us in rows:
            print(f"overhead_{name},{us:.2f},paper_env_ok={ok}")
        print(f"overhead_session_speedup,{session_ratio:.2f},"
              f"target_2x_ok={session_ratio >= 2.0}")
    return rows


def bench_session_vs_blocking(rows, n=2000):
    """Hot-path comparison on the dummy backend.

    Blocking mode: the classic ``@pmt.measure`` wrapper — two synchronous
    ``Sensor.read()`` calls (lock, sample, trapezoid integration, State)
    bracketing every call.  Session mode: ``session.region`` enter/exit —
    sensor-clock timestamps plus a span append; joules resolve later
    against the shared ring buffer, off the measured path.
    """
    blocking = pmt.measure("dummy")(lambda: None)
    us_blocking = _time_per_call(blocking, n=n, repeats=9) * 1e6

    with pmt.Session(["dummy"]) as sess:
        def region_call():
            with sess.region("bench"):
                pass

        us_session = _time_per_call(region_call, n=n, repeats=9) * 1e6
        # Resolution stays correct even though it's off the hot path:
        # constant-watts dummy over a real sleep must yield positive J.
        with sess.region("check") as r:
            time.sleep(0.002)
        assert r.measurements[0].joules > 0.0

    rows.append(("measure_blocking", us_blocking))
    rows.append(("session_region", us_session))
    return us_blocking / max(us_session, 1e-9)


if __name__ == "__main__":
    main()
