"""Batch span-resolution throughput — scalar vs vectorized.

The array-core resolver (repro.core.resolver) resolves many closed spans
per backend in one pass: a single seqlock copy of the ring, one
``np.searchsorted`` over every span endpoint, one fused interpolation of
the cumulative-joules counter.  The previous revision resolved each span
on its own: bisect over Python lists + scalar lerp, twice per span.

This benchmark isolates that resolution math on a synthetic timeline
(no sensors, no threads): spans/second for both paths plus the speedup,
across a batch of spans against a ring of N samples.  Run with --smoke
for CI-sized inputs.

Usage: PYTHONPATH=src python benchmarks/bench_resolve.py [--smoke] [--csv]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.resolver import batch_joules_at
from repro.core.session import _joules_at
from repro.core.state import State


def build_timeline(n: int, seed: int = 0):
    """Synthetic cumulative-joules timeline with duplicate timestamps
    (virtual-clock style) sprinkled in."""
    rng = np.random.default_rng(seed)
    dt = rng.uniform(0.0005, 0.0015, size=n)
    dt[rng.random(n) < 0.01] = 0.0          # duplicates
    ts = np.cumsum(dt)
    watts = 40.0 + 10.0 * np.sin(ts * 3.0)
    js = np.cumsum(watts * dt)
    return ts, js


def make_spans(ts: np.ndarray, m: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    lo, hi = float(ts[0]), float(ts[-1])
    t0 = rng.uniform(lo, hi, size=m)
    t1 = np.minimum(hi, t0 + rng.uniform(0.001, 0.05, size=m))
    return t0, t1


def measure_resolve_throughput(timeline_n: int = 100_000,
                               spans_m: int = 4096,
                               repeats: int = 5) -> dict:
    """Returns ``{scalar_spans_per_s, vectorized_spans_per_s, speedup,
    timeline_n, spans_m, max_abs_err_j}``."""
    ts, js = build_timeline(timeline_n)
    t0, t1 = make_spans(ts, spans_m)

    # Scalar path operates on the legacy list-of-State representation.
    states = [State(timestamp_s=float(t), joules=float(j))
              for t, j in zip(ts, js)]
    ts_list = [float(t) for t in ts]

    def run_vectorized():
        return batch_joules_at(ts, js, t1) - batch_joules_at(ts, js, t0)

    def run_scalar():
        return [(_joules_at(states, ts_list, b)
                 - _joules_at(states, ts_list, a))
                for a, b in zip(t0, t1)]

    best_v = best_s = float("inf")
    for _ in range(repeats):
        t = time.perf_counter()
        jv = run_vectorized()
        best_v = min(best_v, time.perf_counter() - t)
        t = time.perf_counter()
        jsc = run_scalar()
        best_s = min(best_s, time.perf_counter() - t)
    err = float(np.max(np.abs(jv - np.array(jsc))))
    return {
        "timeline_n": timeline_n,
        "spans_m": spans_m,
        "scalar_spans_per_s": spans_m / best_s,
        "vectorized_spans_per_s": spans_m / best_v,
        "speedup": best_s / best_v,
        "max_abs_err_j": err,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    n, m = (20_000, 512) if args.smoke else (100_000, 4096)
    r = measure_resolve_throughput(timeline_n=n, spans_m=m,
                                   repeats=3 if args.smoke else 5)
    print("# PMT batch resolution: scalar (per-span bisect+lerp) vs "
          "vectorized (one searchsorted pass)")
    print(f"timeline={r['timeline_n']} samples, batch={r['spans_m']} spans")
    print(f"scalar:     {r['scalar_spans_per_s']:12.0f} spans/s")
    print(f"vectorized: {r['vectorized_spans_per_s']:12.0f} spans/s")
    print(f"speedup:    {r['speedup']:12.1f}x   "
          f"(max |dJ| = {r['max_abs_err_j']:.2e} J)")
    assert r["max_abs_err_j"] < 1e-9, "vectorized path diverged from scalar"
    if args.csv:
        print(f"resolve_scalar_spans_per_s,{r['scalar_spans_per_s']:.0f}")
        print(f"resolve_vectorized_spans_per_s,"
              f"{r['vectorized_spans_per_s']:.0f}")
        print(f"resolve_speedup,{r['speedup']:.2f}")
    return r


if __name__ == "__main__":
    main()
