"""Paper Fig. 2 — kernel power profiles under PMT, stacked CPU + accel.

Runs the paper's benchmark set (SLEEP, FMA32, STREAM, GRIDDER, DEGRIDDER,
GEMM, JACOBI2D) instrumented with two stacked sensors, exactly like the
paper's stacked decorators: the *measured* host sensor (cpuutil) and the
*modeled* accelerator sensor (tpu — fed the kernel's own compiled cost
analysis).  Kernels execute the Pallas path in interpret mode on CPU; the
TPU energy numbers are the analytical model evaluated on each kernel's
real FLOPs/bytes (kind labels make measured-vs-modeled explicit).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.core as pmt
from repro.core.backends.tpu import TpuCostModelSensor


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis() or {}
    return float(c.get("flops", 0.0)), float(c.get("bytes accessed", 0.0))


def _run(name, fn, args, flops, bytes_, rows, repeats=3):
    """cpu watts: measured over the interpret-mode run.  tpu watts: the
    model evaluated at the kernel's TPU-projected duration (roofline max
    of compute and HBM time) — i.e. what the chip would draw actually
    executing this kernel, which is what reproduces Fig. 2's contrast
    between FLOP-bound, bandwidth-bound and idle kernels."""
    cpu = pmt.create("cpuutil")
    tpu = TpuCostModelSensor.create()
    s_cpu = cpu.read()
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    e_cpu = cpu.read()
    model = tpu.model
    t_tpu = max(flops / model.hw.peak_flops,
                bytes_ / model.hw.hbm_bw, 1e-9)
    w_tpu = model.step_watts(flops, bytes_, 0.0, t_tpu)
    j_tpu = model.step_joules(flops, bytes_, 0.0, t_tpu)
    rows.append((name, dt / repeats, pmt.watts(s_cpu, e_cpu), w_tpu,
                 j_tpu))


def main(csv=False):
    rows = []
    key = jax.random.PRNGKey(0)

    # SLEEP — idle power floor
    cpu = pmt.create("cpuutil")
    tpu = TpuCostModelSensor.create()
    s0, t0r = cpu.read(), tpu.read()
    time.sleep(0.3)
    tpu.account(flops=0, hbm_bytes=0, ici_bytes=0.0, seconds=0.3)
    rows.append(("SLEEP", 0.3, pmt.watts(s0, cpu.read()),
                 pmt.watts(t0r, tpu.read()),
                 tpu.model.static_joules(0.3)))

    from repro.kernels.fma32.ops import fma32
    x = jax.random.normal(key, (1024, 512), jnp.float32)
    # 1024 chained FMAs/element -> 512 FLOP/byte, past the v5e ridge
    # point (240), so the modeled kernel is compute-bound like the paper's
    fn = lambda a: fma32(a, iters=1024, interpret=True)
    f, b = 2.0 * x.size * 1024, 2.0 * x.size * 4
    _run("FMA32", fn, (x,), f, b, rows)

    from repro.kernels.stream.ops import stream_triad
    a = jax.random.normal(key, (4096, 512), jnp.float32)
    bb = jax.random.normal(key, (4096, 512), jnp.float32)
    fn = lambda p, q: stream_triad(p, q, interpret=True)
    f, by = 2.0 * a.size, 3.0 * a.size * 4
    _run("STREAM", fn, (a, bb), f, by, rows)

    from repro.kernels.gridder.ops import degridder, gridder
    P, S, V = 256, 4, 512
    lm = jax.random.uniform(key, (P, 2), minval=-0.5, maxval=0.5)
    uv = jax.random.uniform(key, (S, V, 2), minval=-2, maxval=2)
    vis = jax.random.normal(key, (S, V, 2), jnp.float32)
    f = 8.0 * S * V * P
    by = 4.0 * (S * V * 4 + S * P * 2) * 4
    _run("GRIDDER", lambda *z: gridder(*z, interpret=True), (lm, uv, vis),
         f, by, rows)
    sub = jax.random.normal(key, (S, P, 2), jnp.float32)
    _run("DEGRIDDER", lambda *z: degridder(*z, interpret=True),
         (lm, uv, sub), f, by, rows)

    from repro.kernels.gemm.ops import gemm
    m = jax.random.normal(key, (512, 512), jnp.float32)
    n = jax.random.normal(key, (512, 512), jnp.float32)
    f, by = 2.0 * 512 ** 3, 3.0 * 512 * 512 * 4
    _run("GEMM", lambda p, q: gemm(p, q, block_m=256, block_n=256,
                                   block_k=256, interpret=True), (m, n),
         f, by, rows)

    from repro.kernels.jacobi2d.ops import jacobi2d
    j = jax.random.normal(key, (1024, 512), jnp.float32)
    f, by = 5.0 * j.size, 2.0 * j.size * 4
    _run("JACOBI2D", lambda p: jacobi2d(p, interpret=True), (j,), f, by,
         rows)

    print("# Fig.2 — PMT stacked measurement: CPU (measured) + "
          "TPU (modeled)")
    print(f"{'kernel':10s} {'s/iter':>9s} {'cpu_W':>8s} {'tpu_W':>8s} "
          f"{'tpu_J/iter':>11s}")
    for name, dt, wc, wt, jt in rows:
        print(f"{name:10s} {dt:9.4f} {wc:8.2f} {wt:8.2f} {jt:11.4f}")
    if csv:
        for name, dt, wc, wt, jt in rows:
            print(f"fig2_{name.lower()},{dt*1e6:.1f},"
                  f"cpuW={wc:.2f};tpuW={wt:.2f}")
    return rows


if __name__ == "__main__":
    main()
