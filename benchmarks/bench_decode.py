"""Decode-attention A/B — dense full-cache attend vs length-aware flash-decode.

Decode is memory-bound: every step reads the KV cache once per
attention layer, so the bytes a step *doesn't* read are the direct
J/token lever (PMT's premise: energy-to-solution next to
time-to-solution).  The dense path always touches all ``max_len`` slots
and materialises fp32 scores plus per-step position/validity tensors;
the flash-decode path (``kernels/decode_attention``) reads only the
cache prefix covering each row's ``cur_len`` — on TPU the Pallas
kernel's scalar-prefetch index maps skip the dead blocks before their
HBM reads issue, and the CPU/GPU fallback picks the matching static
prefix from a fused bucket ladder.  The win grows with cache emptiness
(a serving cache is sized for the longest admissible request and
typically runs partially full) and tapers to parity as the cache
genuinely fills.

The A/B drives the *decode-attention layer itself* — the code path this
kernel replaces — with one new token per row against a live cache, at
three fills: an eighth (near-empty), half, and three-quarters.  Both
sides see identical inputs; per-row ``cur_len`` vectors (the
continuous-batching hot path) advance each step.  Measuring the layer
in isolation keeps the comparison about the cache read: a full serve
step adds per-layer scatters, FFNs and logits that are identical under
both impls and only dilute the contrast (bench_serve.py covers the
end-to-end engine).

J/token methodology: each (impl, fill) sweep runs inside a
``pmt.Session`` region on the dummy backend (constant watts), fenced
with ``block_until_ready`` before the region closes — joules track
wall-clock deterministically, J/token = region joules / tokens
attended in the region, and the run reproduces in CI.  On real
hardware the same call sites attribute real sensor energy; only the
backend list changes.

Pass criteria (written into BENCH_decode.json, validated by CI):
flash >= dense on tokens/s AND <= dense on J/token at every measured
fill >= half-full.

Usage: PYTHONPATH=src python benchmarks/bench_decode.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time

import jax
import jax.numpy as jnp

import repro.core as pmt
from repro import configs
from repro.kernels.decode_attention import ops as da_ops
from repro.models import attention as attn_mod

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_decode.json")


def bench_cfg(smoke: bool):
    """GQA bench shape: 8 query heads over 4 KV heads of 64 (gemma2-ish
    ratios), bf16 cache — the serve-path layout."""
    max_len = 2048 if smoke else 4096
    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_heads=8, num_kv_heads=4, head_dim=64)
    return cfg, max_len


def make_steps(cfg, batch: int, max_len: int):
    """Jitted one-token attention steps: (q, cache k/v, cur (B,)) -> out.

    The dense side is exactly what ``decode_self_attention`` runs
    without the flash kernel: build the (1|B, C) slot timeline, mask,
    attend over the whole cache.  The flash side is the
    ``ops.decode_attention`` dispatch (Pallas on TPU / bucketed masked
    lax elsewhere).
    """
    scale = 1.0 / math.sqrt(cfg.query_pre_attn_scalar or cfg.head_dim)

    def dense_step(q, k, v, cur):
        slots = jnp.arange(max_len, dtype=jnp.int32)[None]       # (1,C)
        cur_col = cur[:, None]                                   # (B,1)
        kv_valid = slots <= cur_col
        return attn_mod.attention(
            cfg, q, k.astype(q.dtype), v.astype(q.dtype),
            q_pos=cur_col, kv_pos=slots, causal=True,
            kv_valid=kv_valid, impl="dense")

    def flash_step(q, k, v, cur):
        return da_ops.decode_attention(q, k, v, cur, softcap=cfg.attn_softcap,
                                       scale=scale)

    return {"dense": jax.jit(dense_step), "flash": jax.jit(flash_step)}


def run_impl(step_fn, q, k, v, impl: str, batch: int, fills, steps: int,
             repeats: int):
    """Best-of-``repeats`` per fill on a private dummy-backend session."""

    def sweep(fill, record=None):
        cur = jnp.full((batch,), fill, jnp.int32)
        t0 = time.perf_counter()
        out = None
        for _ in range(steps):
            out = step_fn(q, k, v, cur)
            cur = cur + 1
        jax.block_until_ready(out)
        seconds = time.perf_counter() - t0
        if record is not None:
            record["seconds"] = seconds

    for fill in fills:          # warm jit + allocator, unmeasured
        sweep(fill)

    per_fill = {f: None for f in fills}
    for _ in range(repeats):
        fill_stats = {}
        with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
            mem = sess.add_exporter(pmt.MemoryExporter())
            for fill in fills:
                rec = {}
                with sess.region(f"decode/{impl}/fill{fill}",
                                 tokens=batch * steps):
                    sweep(fill, record=rec)
                fill_stats[fill] = rec
            sess.flush()
            for r in mem.records:
                fill = int(r.path.rsplit("fill", 1)[1])
                d = fill_stats[fill]
                d["joules"] = r.joules
                d["tokens"] = r.tokens
                d["tokens_per_s"] = r.tokens / max(d["seconds"], 1e-9)
                d["j_per_token"] = r.joules / max(r.tokens, 1)
        for f in fills:         # per-fill best wall clock across repeats
            if per_fill[f] is None \
                    or fill_stats[f]["seconds"] < per_fill[f]["seconds"]:
                per_fill[f] = fill_stats[f]
    return {"impl": impl, "fills": {str(f): per_fill[f] for f in fills}}


def main(smoke=False, json_out=DEFAULT_JSON):
    cfg, max_len = bench_cfg(smoke)
    batch = 4
    steps = 16 if smoke else 32
    repeats = 3
    fills = [max_len // 8, max_len // 2, (3 * max_len) // 4]

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (batch, 1, cfg.num_heads, cfg.head_dim),
                          jnp.float32)
    k = jax.random.normal(
        kk, (batch, max_len, cfg.num_kv_heads, cfg.head_dim)).astype(
            jnp.bfloat16)
    v = jax.random.normal(
        kv, (batch, max_len, cfg.num_kv_heads, cfg.head_dim)).astype(
            jnp.bfloat16)

    step_fns = make_steps(cfg, batch, max_len)
    results = {impl: run_impl(step_fns[impl], q, k, v, impl, batch, fills,
                              steps, repeats)
               for impl in ("dense", "flash")}

    print("# decode A/B: dense full-cache attend vs length-aware "
          "flash-decode")
    print(f"{'impl':8s} {'fill':>6s} {'tok/s':>10s} {'J/token':>12s} "
          f"{'seconds':>9s}")
    speedups = {}
    for fill in fills:
        f = str(fill)
        for impl in ("dense", "flash"):
            d = results[impl]["fills"][f]
            print(f"{impl:8s} {fill:6d} {d['tokens_per_s']:10.1f} "
                  f"{d['j_per_token']:12.8f} {d['seconds']:9.3f}")
        dense, flash = results["dense"]["fills"][f], \
            results["flash"]["fills"][f]
        speedups[f] = {
            "tokens_per_s": flash["tokens_per_s"]
            / max(dense["tokens_per_s"], 1e-9),
            "j_per_token_improvement": dense["j_per_token"]
            / max(flash["j_per_token"], 1e-12),
        }
        print(f"#        {fill:6d} flash {speedups[f]['tokens_per_s']:.2f}x "
              f"tokens/s, {speedups[f]['j_per_token_improvement']:.2f}x "
              f"lower J/token")

    gate_fills = [f for f in fills if f >= max_len // 2]
    target_met = all(
        speedups[str(f)]["tokens_per_s"] >= 1.0
        and speedups[str(f)]["j_per_token_improvement"] >= 1.0
        for f in gate_fills)
    print(f"# gate (fills {gate_fills}): "
          f"{'PASS' if target_met else 'FAIL'}")

    if json_out:
        payload = {
            "bench": "pmt_decode",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "shape": "decode attention layer, one token vs live "
                         "cache, per-row cur_len vector",
                "heads": cfg.num_heads,
                "kv_heads": cfg.num_kv_heads,
                "head_dim": cfg.head_dim,
                "cache_dtype": "bfloat16",
                "backend": "dummy",
                "impl_backend": jax.default_backend(),
                "batch": batch,
                "max_len": max_len,
                "steps_per_fill": steps,
                "fills": fills,
                "gate_fills": gate_fills,
            },
            "dense": results["dense"],
            "flash": results["flash"],
            "speedups": speedups,
            "target_met": bool(target_met),
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return bool(target_met)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smaller cache, fewer steps)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_decode.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
