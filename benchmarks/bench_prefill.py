"""Serve admission A/B — blocking bucketed prefill vs chunked-interleaved.

The blocking baseline pays twice on a prefill-heavy mix: every prompt
is left-padded to its power-of-two bucket (up to ~2x wasted prefill
FLOPs — and on the dummy backend's constant watts, wasted joules), and
every admission stalls the entire live decode batch for a whole
prompt's prefill.  Chunked admission (``prefill_chunk``) removes both:
pad waste shrinks to the final partial chunk, and decode advances one
step per prefill chunk, so the head-of-line stall is bounded by one
chunk.

This benchmark runs the same prefill-heavy workload — prompts sitting
just past a bucket boundary (the worst case for bucketing), short
generations — through both admission modes of the *same* continuous
engine and reports tokens/s, J/token, and the p95 decode stall (the
engine's ``stall_events``: seconds decode sat blocked behind each
fenced prefill dispatch).  Per-request spans additionally carry the
``serve/req<N>/prefill`` / ``/decode`` phase split, checked to sum to
each request's total joules.

Pass criteria (written into BENCH_prefill.json, validated by CI via
benchmarks/validate_bench.py):
  * chunked >= 1.2x blocking on tokens/s AND >= 1.2x lower J/token;
  * chunked p95 decode stall <= blocking p95;
  * per-request prefill+decode joules sum to the request total (2%);
  * chunked prefill compiles once; decode compiles once.

Usage: PYTHONPATH=src python benchmarks/bench_prefill.py \
           [--smoke] [--json-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro import configs
from repro.models import model as model_mod
from repro.serve.engine import (Request, ServeEngine, prompt_bucket,
                                stall_p95)

SCHEMA_VERSION = 1
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_prefill.json")


def make_workload(n_requests: int, plen_lo: int, plen_hi: int,
                  max_new_lo: int, max_new_hi: int, vocab: int,
                  seed: int = 0):
    """Prefill-heavy mix: prompt lengths uniform just past a power-of-
    two boundary (bucket waste 1.3-2x), short generations."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(plen_lo, plen_hi + 1))
        reqs.append(Request(
            prompt=rng.integers(0, vocab, size=plen).tolist(),
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi + 1))))
    return reqs


def run_mode(cfg, params, workload, prefill_chunk: int, batch: int,
             max_len: int, repeats: int = 1):
    """Best-of-``repeats`` run on a private dummy-backend session.

    The engine is warmed (each prompt bucket / the chunk shape) before
    the session attaches and the clock starts, so both modes measure
    steady-state serving, not jit compilation.  fp32 caches for both
    modes: CPU has no native bf16, so bf16 storage would tax every
    chunk (and every decode step) with conversion copies and the A/B
    would partly measure dtype casts instead of admission policy."""
    eng = ServeEngine(cfg, params, batch_size=batch, max_len=max_len,
                      session=None, prefill_chunk=prefill_chunk,
                      cache_dtype=jnp.float32)
    if prefill_chunk:
        warm = [Request(prompt=[1] * (prefill_chunk + 1), max_new_tokens=2)]
        eng.generate(warm)
    else:
        for bucket in sorted({prompt_bucket(len(r.prompt))
                              for r in workload}):
            eng.generate([Request(prompt=[1] * bucket, max_new_tokens=2)])
    best = None
    for _ in range(repeats):
        with pmt.Session(["dummy"], pool=pmt.SensorPool()) as sess:
            mem = sess.add_exporter(pmt.MemoryExporter())
            eng.session = sess
            reqs = [dataclasses.replace(r) for r in workload]
            t0 = time.perf_counter()
            done = eng.generate(reqs)
            seconds = time.perf_counter() - t0
            eng.session = None
            sess.flush()
            if best is not None and seconds >= best["seconds"]:
                continue
            tokens = sum(len(r.out) for r in done)
            agg = [r for r in mem.records
                   if r.path.startswith("serve/batch")]
            whole = {}
            phases = {}
            for r in mem.records:
                if not r.path.startswith("serve/req"):
                    continue
                req, _, phase = r.path.replace("serve/", "").partition("/")
                if phase:
                    phases.setdefault(req, {})[phase] = r.joules
                else:
                    whole[req] = {"joules": r.joules, "tokens": r.tokens}
            joules = sum(r.joules for r in agg)
            split_errs = []
            per_request = []
            for req in sorted(whole):
                ph = phases.get(req, {})
                total = whole[req]["joules"]
                split = ph.get("prefill", 0.0) + ph.get("decode", 0.0)
                if total > 0:
                    split_errs.append(abs(split - total) / total)
                per_request.append({
                    "path": f"serve/{req}",
                    "tokens": whole[req]["tokens"],
                    "joules": total,
                    "prefill_joules": ph.get("prefill", 0.0),
                    "decode_joules": ph.get("decode", 0.0),
                })
            best = {
                "mode": "chunked" if prefill_chunk else "blocking",
                "prefill_chunk": prefill_chunk,
                "seconds": seconds,
                "tokens": tokens,
                "tokens_per_s": tokens / max(seconds, 1e-9),
                "joules": joules,
                "j_per_token": joules / max(tokens, 1),
                "stall_events": len(eng.stall_events),
                "p95_decode_stall_s": stall_p95(eng.stall_events),
                "max_phase_split_rel_err": max(split_errs) if split_errs
                else 0.0,
                "per_request": per_request,
                "request_token_sum": int(sum(d["tokens"]
                                             for d in per_request)),
                "compile_counts": dict(eng.compile_counts),
            }
    return best


def main(smoke=False, json_out=DEFAULT_JSON):
    # Bench-local config: big enough that a prefill chunk / decode step
    # is compute-bound (~10s of ms on CPU), so the A/B measures
    # admission policy rather than per-dispatch overhead.  Prompts land
    # just past a power-of-two boundary — bucketing's documented worst
    # case: (256, 320] buckets to 512 (1.6-2x pad FLOPs/joules), while
    # chunk-160 admission pads to 320 (two chunks; small chunks trade
    # more of the win for a tighter stall bound — the CPU pays a fixed
    # ~5 ms per dispatched chunk that a TPU pipeline would hide).
    # Each mode also gets the max_len its admission policy actually
    # needs (bucket + max_new vs chunk-padded prompt + max_new): the
    # oversized per-slot cache — and the cost of attending/scattering
    # it on every later step — is part of what bucketing buys you.
    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32",
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
        vocab_size=1024, attn_chunk=128)
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    chunk = 160
    batch = 2
    n_requests = 4 if smoke else 12
    plen_lo, plen_hi = 257, 320
    max_new_lo, max_new_hi = 2, 3
    repeats = 1 if smoke else 2
    workload = make_workload(n_requests, plen_lo, plen_hi, max_new_lo,
                             max_new_hi, cfg.vocab_size)
    bucket = prompt_bucket(plen_hi)
    max_len_blocking = bucket + max_new_hi
    padded_hi = -(-plen_hi // chunk) * chunk
    max_len_chunked = padded_hi + max_new_hi

    blocking = run_mode(cfg, params, workload, 0, batch, max_len_blocking,
                        repeats)
    chunked = run_mode(cfg, params, workload, chunk, batch,
                       max_len_chunked, repeats)

    speedup = chunked["tokens_per_s"] / max(blocking["tokens_per_s"], 1e-9)
    jpt_ratio = blocking["j_per_token"] / max(chunked["j_per_token"], 1e-12)
    stall_ok = chunked["p95_decode_stall_s"] \
        <= blocking["p95_decode_stall_s"] or blocking["stall_events"] == 0
    split_ok = max(blocking["max_phase_split_rel_err"],
                   chunked["max_phase_split_rel_err"]) <= 0.02
    compiles_ok = (chunked["compile_counts"]["prefill_chunk"] == 1
                   and chunked["compile_counts"]["decode"] == 1
                   and chunked["compile_counts"]["prefill"] == 0)
    target_met = bool(speedup >= 1.2 and jpt_ratio >= 1.2 and stall_ok
                      and split_ok and compiles_ok)

    print("# serve admission A/B: blocking bucketed vs chunked-interleaved")
    print(f"{'mode':10s} {'tok/s':>9s} {'J/token':>10s} {'seconds':>9s} "
          f"{'p95 stall':>12s} {'compiles(p/c/d)':>16s}")
    for d in (blocking, chunked):
        cc = d["compile_counts"]
        print(f"{d['mode']:10s} {d['tokens_per_s']:9.1f} "
              f"{d['j_per_token']:10.4f} {d['seconds']:9.3f} "
              f"{d['p95_decode_stall_s'] * 1e3:9.2f} ms "
              f"{cc['prefill']:>6d}/{cc['prefill_chunk']}/{cc['decode']}")
    print(f"# chunked vs blocking: {speedup:.2f}x tokens/s, "
          f"{jpt_ratio:.2f}x lower J/token, stall p95 "
          f"{chunked['p95_decode_stall_s'] * 1e3:.2f} vs "
          f"{blocking['p95_decode_stall_s'] * 1e3:.2f} ms "
          f"({'PASS' if target_met else 'FAIL'})")
    print(f"# phase split: max |prefill+decode - total|/total = "
          f"{max(blocking['max_phase_split_rel_err'], chunked['max_phase_split_rel_err']):.4f} "
          f"({'OK' if split_ok else 'MISMATCH'})")

    if json_out:
        payload = {
            "bench": "pmt_prefill",
            "schema_version": SCHEMA_VERSION,
            "smoke": bool(smoke),
            "workload": {
                "arch": "smollm-135m (bench-scaled reduced cfg: 4L/d256, "
                        "fp32)",
                "backend": "dummy",
                "n_requests": n_requests,
                "batch": batch,
                "max_len": {"blocking": max_len_blocking,
                            "chunked": max_len_chunked},
                "prompt_lengths": [plen_lo, plen_hi],
                "max_new_tokens": [max_new_lo, max_new_hi],
                "prefill_chunk": chunk,
            },
            "blocking": blocking,
            "chunked": chunked,
            "speedup_tokens_per_s": speedup,
            "jpt_improvement": jpt_ratio,
            "stall_p95_improved": bool(stall_ok),
            "phase_split_sums_to_total": bool(split_ok),
            "chunked_prefill_compiles_once": bool(compiles_ok),
            "target_met": target_met,
        }
        with open(json_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_out}")
    return target_met


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer/shorter requests)")
    ap.add_argument("--json-out", default=DEFAULT_JSON,
                    help="where to write BENCH_prefill.json ('' disables)")
    a = ap.parse_args()
    ok = main(smoke=a.smoke, json_out=a.json_out)
    raise SystemExit(0 if ok else 1)
