"""Reproduce the *shape* of the paper's Fig. 2 as a dump-mode timeline:
idle -> compute-bound (FMA) -> bandwidth-bound (STREAM) -> GEMM, with the
stacked CPU (measured) + TPU (modeled) sensors, then render the power
trace as ASCII.

Run: PYTHONPATH=src python examples/power_timeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as pmt
from repro.core.backends.tpu import TpuCostModelSensor
from repro.kernels.fma32.ops import fma32
from repro.kernels.gemm.ops import gemm
from repro.kernels.stream.ops import stream_triad


def main():
    cpu = pmt.create("cpuutil")
    tpu = TpuCostModelSensor.create()

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1024, 512), jnp.float32)
    a = jax.random.normal(key, (2048, 512), jnp.float32)
    b = jax.random.normal(key, (2048, 512), jnp.float32)
    m = jax.random.normal(key, (512, 512), jnp.float32)

    phases = []
    with cpu.dumping("/tmp/fig2_cpu.pmt", period_s=0.05), \
            tpu.dumping("/tmp/fig2_tpu.pmt", period_s=0.05):
        for name, fn, (fl, by) in [
            ("IDLE", lambda: time.sleep(0.6), (0, 0)),
            ("FMA32", lambda: jax.block_until_ready(
                fma32(x, iters=128, interpret=True)),
             (2.0 * x.size * 128, 2.0 * x.size * 4)),
            ("STREAM", lambda: jax.block_until_ready(
                stream_triad(a, b, interpret=True)),
             (2.0 * a.size, 3.0 * a.size * 4)),
            ("GEMM", lambda: jax.block_until_ready(
                gemm(m, m, interpret=True)),
             (2.0 * 512 ** 3, 3.0 * 512 * 512 * 4)),
        ]:
            t0 = time.perf_counter()
            fn()
            dt = time.perf_counter() - t0
            tpu.account(flops=fl, hbm_bytes=by, ici_bytes=0.0,
                        seconds=max(dt, 1e-3))
            phases.append((name, dt))
            time.sleep(0.3)

    for path, label in (("/tmp/fig2_cpu.pmt", "CPU (measured)"),
                        ("/tmp/fig2_tpu.pmt", "TPU (modeled)")):
        _, recs = pmt.read_dump(path)
        w = np.array([r.watts for r in recs])
        if not len(w):
            continue
        lo, hi = w.min(), max(w.max(), w.min() + 1e-3)
        bars = ((w - lo) / (hi - lo) * 7).astype(int)
        blocks = "▁▂▃▄▅▆▇█"
        print(f"{label:16s} [{lo:6.1f}W..{hi:6.1f}W] "
              + "".join(blocks[i] for i in bars))
    print("phases:", ", ".join(f"{n}({dt:.2f}s)" for n, dt in phases))
    print("timelines: /tmp/fig2_cpu.pmt /tmp/fig2_tpu.pmt")


if __name__ == "__main__":
    main()
