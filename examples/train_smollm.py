"""End-to-end training example: SmolLM-135M with PMT energy monitoring.

The full 135M model trains for a few hundred steps with ``--full`` (slow
on CPU but real); the default preset is the reduced config so the example
finishes in ~a minute and demonstrably learns (loss drops on the synthetic
Markov stream).  Checkpoint/restart (with energy continuity) is exercised
by interrupting and re-running with the same --ckpt-dir.

Run: PYTHONPATH=src python examples/train_smollm.py [--full] [--steps N]
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # defer CLI to launch.train's parser below
from repro.launch import train as train_launcher  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="the real 135M config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/smollm_ckpt")
    args, _ = ap.parse_known_args()

    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--ckpt-dir", args.ckpt_dir,
            "--energy-log", "/tmp/smollm_energy.csv", "--log-every", "20"]
    if not args.full:
        argv.append("--reduced")
    train_launcher.main(argv)
    print("energy log: /tmp/smollm_energy.csv")
