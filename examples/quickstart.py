"""PMT quickstart — the unified ``pmt.Session`` API, plus the paper's
classic Listings 1 and 2 as the shims they have become.

Run: PYTHONPATH=src python examples/quickstart.py

Migration table (old call -> new call):

    sensor = pmt.create("x")             sess = pmt.Session(["x"])
    a = sensor.read(); work(); b = ...   with sess.region("roi") as r: work()
    sensor.joules(a, b)                  r.measurement.joules
    @pmt.measure("x")                    with sess.region("roi"):
    with pmt.Region("x") as r: ...       with sess.region("roi") as r: ...
    sensor.start_dump_thread(f)          sess.add_exporter(pmt.CsvExporter(f))
    pmt.PowerMonitor(["x"])              pmt.PowerMonitor(session=sess)

The old calls all still work — they now draw shared sensors from the
process-wide pool instead of constructing private copies.
"""
import contextlib
import os
import time

import repro.core as pmt


def session_mode():
    """The unified API: one shared background sampler per backend,
    non-blocking nested regions, structured export.

    Performance model (the array-core redesign):

      * The background sampler writes into a preallocated NumPy ring —
        zero Python allocations per tick in steady state, readers use
        seqlock retries instead of locks, so sampling never stalls and
        nothing stalls on sampling.
      * ``region(...)`` entry/exit reads only the sensor clock: exit is
        an O(1) span enqueue (a few microseconds), ~an order of
        magnitude cheaper than resolving on close (see
        benchmarks/bench_overhead.py and BENCH_overhead.json).
      * Resolution happens on a background resolver thread, many spans
        per batch in one vectorized ``np.searchsorted`` pass, then fans
        out to exporters.

    When do results become available?  ``r.measurements`` is
    *future-style*: the value exists (a) as soon as the resolver has
    processed the span — typically within a couple of sampling periods
    of region exit, with records reaching exporters on their own — or
    (b) immediately when you ask: ``r.measurements``, ``sess.flush()``
    and ``sess.close()`` all resolve synchronously, taking at most one
    closing sensor sample per backend.  Loops that only export (serve
    waves, train steps) never wait.  A region that outlives the ring
    (capacity x period) resolves with ``window_evicted=True`` instead of
    silently under-reporting energy.
    """
    with contextlib.suppress(FileNotFoundError):
        os.remove("/tmp/pmt_regions.jsonl")   # exporter appends
    with pmt.Session(["cpuutil", "tpu"]) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        sess.add_exporter(pmt.JsonlExporter("/tmp/pmt_regions.jsonl"))

        with sess.region("pipeline"):                 # nests
            with sess.region("load"):
                time.sleep(0.2)
            with sess.region("compute", tokens=512) as r:
                time.sleep(0.5)

        # Region exit was O(1); asking for the numbers resolves the span
        # (or returns the cached result if the resolver got there first).
        was_async = r.resolved
        print(f"compute: {r.measurements.total_joules():.4f} J "
              f"across {len(r.measurements)} sensors "
              f"(resolved in background: {was_async})")
        sess.flush()                                  # resolve + export rest
        print(f"session stats: {sess.stats()}")
        for rec in mem.records:
            print(f"  {rec.path:18s} {rec.sensor:8s} {rec.joules:9.4f} J "
                  f"{rec.watts:8.3f} W {rec.seconds:6.3f} s")
    print("structured export -> /tmp/pmt_regions.jsonl "
          f"({len(pmt.read_jsonl('/tmp/pmt_regions.jsonl'))} records)")


def listing1_measurement_mode():
    """C++ Listing 1: create -> read -> work -> read -> derive.

    Still supported verbatim; the Session equivalent is region() above.
    """
    sensor = pmt.create("cpuutil")          # measured host-CPU backend
    start = sensor.read()
    time.sleep(1.0)                          # the paper sleeps 5 s; 1 s here
    end = sensor.read()
    print(f"{sensor.joules(start, end):9.4f} [J]")
    print(f"{sensor.watts(start, end):9.4f} [W]")
    print(f"{sensor.seconds(start, end):9.4f} [S]")


def listing2_decorators():
    """Python Listing 2: stacked decorators — now shims drawing shared
    sensors from the default session's pool."""

    @pmt.measure("tpu")        # modeled accelerator sensor
    @pmt.measure("cpuutil")    # measured host sensor
    def my_application():
        time.sleep(0.5)
        return 42

    measures = my_application()
    for m in measures:
        print(m)
    print("wrapped result:", measures.result)


def serving_mode():
    """Serving: continuous batching with per-request, per-phase J/token.

    The ``ServeEngine`` decodes over fixed slots with *per-slot position
    counters*: a finished request's slot is refilled from the queue on
    the next step (its KV row is scattered in place via the
    ``kernels/cache_update`` Pallas kernel on TPU), so short requests
    never idle behind long ones the way synchronized waves force them
    to.

    Admission is **chunked prefill interleaved with decode**: the
    prompt is processed ``prefill_chunk`` tokens at a time through the
    ``kernels/prefill_attention`` flash kernel (each chunk attends the
    request's already-written cache prefix plus its own causal keys,
    then scatters its KV slice in place), and the scheduler runs one
    chunk per decode step.  Prefill therefore compiles **once** — at
    one chunk shape, for any prompt length — pad waste shrinks from
    up-to-2x power-of-two bucketing to the final partial chunk, and an
    admission stalls the live decode batch for at most one chunk
    instead of a whole prompt.  The knob: ``cfg.prefill_chunk`` /
    ``PMT_PREFILL_CHUNK`` / ``ServeEngine(prefill_chunk=...)`` /
    ``repro.launch.serve --prefill-chunk``.

    Migration note (buckets removed): ``prefill_chunk=0`` keeps the
    old *blocking bucketed* admission (one whole-prompt prefill per
    request, left-padded to its power-of-two ``prompt_bucket``) as the
    measured baseline — and it is the automatic fallback for
    encoder-decoder archs.  Bucketed prefill left-pads, so pad tokens
    sit in context at the start of the sequence and shift every RoPE
    position; chunked prefill processes the exact prompt from position
    0.  For prompts that are not already bucket-sized the two can
    therefore generate different tokens — chunked is the faithful
    computation, and the one whole-prompt (unpadded) prefill agrees
    with (see tests/test_serve_chunked.py).  Sampling is a constructor
    knob too: ``ServeEngine(greedy=False, temperature=..., seed=...)``
    threads a per-step PRNG key into the decode draw.

    Energy attribution is three-level and fully non-blocking:

      * one aggregate region per ``generate()`` call
        (``serve/batch<N>``) whose token count is the *actually
        generated* total — never ``batch * max_steps`` padding;
      * one flat span per request (``serve/req<N>``, admission ->
        last token) resolved off the shared background ring sampler, so
        each request gets its own J/token.  Token counts across request
        spans sum exactly to the aggregate;
      * two *phase* child scopes per request tiling its span —
        ``serve/req<N>/prefill`` (token count = prompt length) and
        ``serve/req<N>/decode`` (token count = generated tokens) — so
        the time-to-first-token joules and the steady-state decode
        joules report separately and sum to the request total
        (``PowerMonitor.per_request_energy()`` carries the same split
        as ``prefill_joules`` / ``decode_joules``).

    benchmarks/bench_serve.py A/Bs continuous batching against the
    synchronized-wave baseline (``mode="wave"``), and
    benchmarks/bench_prefill.py A/Bs chunked-interleaved admission
    against blocking-bucketed (tokens/s, J/token, p95 decode stall);
    see BENCH_serve.json / BENCH_prefill.json for the numbers.

    Decode attention impl selection: decode is memory-bound, so HBM
    bytes are joules — ``ServeEngine(..., decode_attn_impl=...)`` (or
    ``--decode-attn-impl`` on ``repro.launch.serve``, or
    ``cfg.decode_attn_impl``) picks how each decode step reads the KV
    cache:

      * ``"flash"`` — the ``kernels/decode_attention`` flash-decode
        family: a Pallas kernel on TPU whose scalar-prefetch index
        maps skip cache blocks beyond each row's position *before
        their HBM reads issue* (ring-buffer arithmetic, GQA packing,
        and soft-capping live in-kernel), with a segmented masked-lax
        twin elsewhere.  Wins whenever caches run partially full —
        the common serving case, since ``max_len`` is sized for the
        longest admissible request: ~2x tokens/s and J/token at
        half-full caches on the bench config, converging toward
        parity only as the cache truly fills.
      * ``"dense"`` — masked attend over the whole cache every step;
        the simple baseline and the reference numbers.
      * ``"auto"`` (default) — flash on TPU, dense elsewhere; the
        ``PMT_DECODE_ATTN_IMPL`` env var overrides for experiments.

    benchmarks/bench_decode.py A/Bs the two at several cache fills
    with tokens/s *and* J/token attributed through Session regions
    (see BENCH_decode.json).
    """
    import dataclasses

    import jax

    from repro import configs
    from repro.models import model as model_mod
    from repro.serve.engine import Request, ServeEngine

    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32")
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    with pmt.Session(["dummy"]) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          session=sess)
        done = eng.generate([Request(prompt=[1, 2, 3], max_new_tokens=8),
                             Request(prompt=[4, 5], max_new_tokens=2),
                             Request(prompt=[6], max_new_tokens=5)])
        sess.flush()
        tokens = sum(len(r.out) for r in done)
        for rec in mem.records:
            if rec.path.startswith("serve/"):
                print(f"  {rec.path:16s} {rec.tokens:4d} tok "
                      f"{rec.joules:9.4f} J "
                      f"{rec.joules / max(rec.tokens, 1):9.5f} J/token")
        print(f"served {len(done)} requests / {tokens} tokens; decode "
              f"compiled {eng.compile_counts['decode']}x, chunked "
              f"prefill {eng.compile_counts['prefill_chunk']}x (one "
              f"shape each)")


def paged_mode():
    """Paged KV cache: block pools, radix prefix reuse, batched admission.

    ``ServeEngine(kv_layout="paged")`` replaces the contiguous
    per-slot KV rows with **block-paged** storage: one physical pool of
    ``(pages, page_size, ...)`` blocks per cache leaf (page 0 reserved
    as a write-off scratch page), a host-side allocator, and one
    ``(batch, pages_per_slot)`` int32 page table shared by every layer.
    The serving kernels take the table through scalar-prefetch
    ``BlockSpec`` index maps, so logical position ``t`` of row ``b``
    reads physical page ``table[b, t // page_size]`` with no gather
    materialised — and the layout is *transparent*: paged and
    contiguous engines generate byte-identical tokens
    (tests/test_serve_paged.py gates this across GQA, sliding-window,
    and MLA-latent cache families).

    What paging buys:

      * **No per-slot reservation** — a slot's pages are allocated at
        admission and freed at retirement, so a pool sized well under
        ``batch * max_len`` serves the same workload; admissions wait
        on pages instead of over-provisioned rows
        (``kv_pool_pages=...`` / ``--kv-pool-blocks``).
      * **Radix-tree prefix reuse** — retired requests' full pages are
        adopted (refcounted, copy-free) into a radix tree keyed on
        token ids; a new request whose prompt shares a cached prefix
        maps those pages into its table and resumes prefill at the
        match point.  Saved work is *priced*: the engine learns J/token
        from resolved prefill spans and accrues
        ``saved_prefill_joules`` for every reused token.  LRU eviction
        reclaims tree pages under pool pressure; ``prefix_cache=False``
        / ``--no-prefix-cache`` opts out.
      * **Batched chunk admissions** — every pending admission's next
        chunk rides ONE ``(batch, chunk)`` prefill dispatch at per-row
        offsets (passenger rows masked to the scratch page), so
        concurrent arrivals stop queueing behind each other's chunks.
      * **Cache gauges** — ``engine.stats()["kv_cache"]`` (and the
        telemetry ``/stats`` endpoint) reports pages free/used, prefix
        hit rate, evictions, and saved prefill joules live; a governor
        with ``pool_reserve_frac>0`` vetoes admissions when the free
        fraction drops below the reserve.

    Migration note: the contiguous layout stays the default
    (``kv_layout="contiguous"``) and the only choice for state-carrying
    (mamba/xlstm) and encoder-decoder archs; paged requires chunked
    continuous admission (``prefill_chunk > 0``).  Sliding-window
    layers store *unwrapped* pages (window applied as an explicit mask)
    rather than the contiguous path's ring buffer, which is why a page
    never has to be rewritten when the window slides.

    Migration note (MoE determinism under prefix reuse): a warm
    request resumes prefill at its radix match point, and when that
    offset is off the cold run's chunk grid the float reductions
    reorder by ~1 ulp.  Dense archs absorb this (same argmax), but MoE
    routers can flip near-tied top-k choices and diverge from the cold
    tokens.  If you need bit-identical warm/cold MoE serving, keep
    resume offsets on the chunk grid — set ``kv_page_size ==
    prefill_chunk`` (tests/test_serve_paged.py gates the MoE arch
    exactly this way) — or disable reuse with ``prefix_cache=False``.
    benchmarks/bench_paged.py measures admitted concurrency at a fixed
    cache-memory budget, J/token parity, and warm-vs-cold first-token
    latency (BENCH_paged.json).
    """
    import dataclasses

    import jax

    from repro import configs
    from repro.models import model as model_mod
    from repro.serve.engine import Request, ServeEngine

    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32")
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    with pmt.Session(["dummy"]) as sess:
        eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                          session=sess, kv_layout="paged", kv_page_size=8)
        prompts = [[7, 3, 9, 1, 4, 2, 8, 5, 6, 1, 2, 3],   # shared prefix
                   [7, 3, 9, 1, 4, 2, 8, 5, 9, 9],          # ... with this
                   [5, 5, 5]]
        eng.generate([Request(prompt=p, max_new_tokens=4) for p in prompts])
        # second round: prompts 0/1 share pages the tree now holds
        eng.generate([Request(prompt=p, max_new_tokens=4) for p in prompts])
        sess.flush()
        kc = eng.stats()["kv_cache"]
        print(f"  pool {kc['pages_used']}/{kc['pages_total']} pages held, "
              f"prefix hits {kc['prefix_hits']}/{kc['prefix_lookups']} "
              f"({kc['prefix_hit_tokens']} tokens reused, "
              f"{kc['prefix_evictions']} evictions)")


def quantized_mode():
    """Quantized KV caches: int8 / fp8 rows, dequantized in-kernel.

    Decode is memory-bound, so cache bytes are joules (see
    ``serving_mode``).  ``ServeEngine(cache_dtype="int8")`` (or
    ``"fp8_e4m3"``) halves the bytes every decode step streams:

      * **Write side** — the ``kernels/cache_update`` family quantizes
        each K/V row at admission/decode scatter time: symmetric
        per-(token, kv-head) absmax scaling over the head dim, int8 (or
        fp8-e4m3) codes plus one f32 scale per row per kv-head.  In the
        paged layout scales live page-granular beside the code pages
        and ride the same page table.
      * **Read side** — the decode/prefill attention kernels
        (contiguous + paged) dequantize K and V *in-register* inside
        the online-softmax loop: codes stream from HBM at 1 byte/elem
        and widen to f32 only in the block actually being attended.  No
        dequantized copy of the cache ever exists in memory.  MLA's
        latent cache quantizes once — the same quantized rows serve as
        both key and value (the v-width alias), preserving the
        slice-then-dequant == dequant-then-slice identity.
      * **Accuracy** — serve-path logit drift vs the bf16 cache stays
        under 1% (int8) / ~1.4% (fp8) of max |logit| on the reduced
        gate configs; tests/test_quant_serve.py gates all three cache
        families (GQA, sliding-window ring, MLA latent) at 10%/20%
        relative bounds, and every quantized kernel has a blockwise
        reference twin it must match bit-exactly in interpret mode
        (tests/test_quant_kernels.py).
      * **Payoff** — benchmarks/bench_quant.py A/Bs int8/fp8 against
        bf16 at several cache fills: int8 reaches ~1.3x tokens/s and
        ~0.75x J/token at half-full 8k caches where the working set
        exceeds cache-resident sizes (BENCH_quant.json; fp8 matches
        int8's bytes but pays software f8 conversion off-TPU, so only
        int8 carries the perf gate).

    The knob is uniform: ``cfg.kv_quant`` / ``ServeEngine(
    cache_dtype="int8")`` / ``repro.launch.serve --cache-dtype int8``.
    ``stats()["kv_cache"]`` reports ``cache_dtype`` and
    ``bytes_per_token`` for both layouts, and prefix-cache savings are
    priced at the engine's *own* learned J/token — a quantized engine
    never bills at a bf16 engine's rate.
    """
    import dataclasses

    import jax

    from repro import configs
    from repro.models import model as model_mod
    from repro.serve.engine import Request, ServeEngine

    cfg = dataclasses.replace(
        configs.get_config("smollm-135m", reduced=True), dtype="float32")
    params, _ = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[3, 1, 4, 1, 5, 9, 2, 6], [2, 7, 1, 8], [1, 1, 2, 3, 5]]
    outs = {}
    with pmt.Session(["dummy"]) as sess:
        for cache_dtype in ("bfloat16", "int8"):
            eng = ServeEngine(cfg, params, batch_size=2, max_len=64,
                              session=sess, kv_layout="paged",
                              kv_page_size=8, cache_dtype=cache_dtype)
            done = eng.generate([Request(prompt=p, max_new_tokens=6)
                                 for p in prompts])
            kc = eng.stats()["kv_cache"]
            outs[cache_dtype] = [r.out for r in done]
            print(f"  {kc['cache_dtype']:>8s}: "
                  f"{kc['bytes_per_token']:6.1f} B/token")
        agree = sum(a == b for a, b in zip(outs["bfloat16"], outs["int8"]))
        print(f"  int8 vs bf16 greedy tokens: {agree}/{len(prompts)} "
              f"requests identical (drift gates are on logits; see "
              f"tests/test_quant_serve.py)")


def telemetry_mode():
    """Live telemetry & power capping: the energy *control* plane.

    Everything above measures; this closes the loop.  Two pieces, both
    stdlib-only:

      * :class:`repro.telemetry.PowerRecorder` — subscribes to the
        session's ``MemoryExporter`` (resolved records) and polls each
        backend's ring sampler (watts timelines) into bounded in-memory
        rings, without perturbing the measurement plane.
        :class:`repro.telemetry.TelemetryServer` serves it over plain
        HTTP on an ephemeral (or fixed) port — ``/timeline`` (power
        series), ``/requests`` (per-request prefill/decode joules, with
        the raw records round-trippable via ``RegionRecord.from_json``),
        ``/stats`` (engine counters), and ``/stream``, a live SSE feed
        of every newly resolved record (``curl -N .../stream``).
      * :class:`repro.serve.PowerGovernor` — a policy object the
        ``ServeEngine`` consults at admission, chunk-drain, and decode
        points.  It reads smoothed window power from the recorder and
        holds the engine under a watts cap by (in escalating order)
        gating/spacing admissions — with a *learned* per-admission
        power step, so it blocks an admission whose settled load would
        overshoot — pausing prefill chunks, and duty-cycling decode.
        Per-tenant joules quotas deprioritize over-quota tenants at
        admission without ever starving them.  Every throttle decision
        is a ``serve/governor/<action>`` span in the same export
        stream as the requests it shaped.

    The launcher wires it all up: ``repro.launch.serve
    --power-cap-watts 120 --telemetry-port 8321 --tenant-quota 50``.
    benchmarks/bench_governor.py proves the loop on a load-coupled
    dummy backend (watts tracks engine ``live_slots``): the cap holds
    within 5% while every request completes (BENCH_governor.json).

    Subscriber-exporter contract (see the Session docstring): exporter
    and recorder callbacks run on the *resolving* thread and must not
    block — the SSE fan-out uses bounded drop-oldest per-client queues
    for exactly that reason.
    """
    from repro.serve.engine import Request, ServeEngine
    from repro.telemetry import PowerRecorder, TelemetryServer
    import json
    import urllib.request

    with pmt.Session(["dummy"]) as sess:
        mem = sess.add_exporter(pmt.MemoryExporter())
        with PowerRecorder().attach(sess, exporter=mem) as recorder:
            with sess.region("warmup"):
                time.sleep(0.05)
            sess.flush()
            recorder.poll_once()
            with TelemetryServer(recorder) as srv:   # port=0: ephemeral
                stats = json.loads(urllib.request.urlopen(
                    srv.url + "/stats", timeout=5.0).read())
                timeline = json.loads(urllib.request.urlopen(
                    srv.url + "/timeline?window=5", timeout=5.0).read())
                n = sum(len(s) for s in timeline["series"].values())
                print(f"telemetry at {srv.url}: {stats['records']} records, "
                      f"{n} watts samples, window mean "
                      f"{timeline['window_mean_watts']:.1f} W")


def fault_tolerance_mode():
    """Degraded mode: what happens when the power sensor itself fails.

    Real power counters drop samples, hang, reset mid-run, and report
    garbage.  The measurement plane treats sensor failure as a state to
    *survive and label*, never a reason to crash — or worse, to
    silently interpolate energy that was never measured:

      * :class:`repro.core.SensorSupervisor` wraps a failover chain of
        backends behind the ordinary ``Sensor`` interface: per-read
        deadline, bounded retries with jittered exponential backoff, a
        circuit breaker per backend (open after N consecutive failures,
        half-open probe after a cooldown), and sanitization — NaN /
        negative watts rejected, a MAD-based spike gate, and joules
        counter-reset *rebasing* so a RAPL wraparound shows up as a
        monotonic series plus a ``counter_resets`` tick instead of a
        negative energy delta.  Health is a three-state machine
        (``OK``/``DEGRADED``/``FAILED``) with transition callbacks.
      * The background ``RingSampler`` survives any read exception:
        errors are warned (rate-limited) and counted, and the outage
        becomes a *coverage gap*.  Spans that overlap a gap resolve
        with ``degraded=True`` — carried through ``Measurement``,
        ``RegionRecord``, JSONL/CSV export, and ``session.stats()`` —
        because an energy integral over a blackout is a lower bound,
        not a measurement.
      * :class:`repro.serve.PowerGovernor` takes ``signal_ttl_s`` +
        ``fail_mode``: when the watts signal goes stale, ``"closed"``
        (default) stops admitting new work until the signal returns
        (never throttling blind — a frozen window reading would
        otherwise keep reporting its last value forever), ``"open"``
        keeps serving uncapped.  Either way it *re-establishes the cap
        automatically when samples resume*.
      * ``PowerRecorder`` polls sampler/supervisor health and emits
        ``HealthEvent`` transitions on the SSE stream (``event:
        health``) and the ``/health`` endpoint.

    Fault injection (:class:`repro.core.FaultInjectingSensor`) scripts
    all of this deterministically — the fault matrix: ``error`` (read
    raises), ``hang`` (slow read), ``nan`` / ``negative`` / ``spike``
    (garbage watts), ``stuck`` (frozen sample), ``reset`` (joules
    counter restarts), ``flap`` (intermittent error) — windowed by
    read index (bit-exact tests) or by time relative to ``arm()``
    (live chaos runs).  benchmarks/bench_faults.py drives a governed
    serve run through a blackout + flap and gates on: sampler thread
    alive, every request complete, blackout spans ``degraded``, cap
    re-held after recovery, supervised reads <= 1.1x raw
    (BENCH_faults.json).  The launcher flag ``repro.launch.serve
    --supervise`` wraps each backend in a supervisor with a fail-safe
    fallback.
    """
    # Short index-window blackout: once the breaker opens, the faulted
    # window drains at one half-open probe per cooldown, so it must be
    # only a few reads long to clear within the demo region.
    blackout = pmt.Fault("error", start=20, count=3)

    # With a fallback in the chain, a primary blackout is a non-event:
    # reads fail over (then back), no gap, nothing degraded.
    flaky = pmt.FaultInjectingSensor(pmt.create("dummy", watts=60.0),
                                     plan=[blackout])
    sup = pmt.SensorSupervisor(
        [flaky, pmt.create("dummy", watts=60.0)],
        retries=1, backoff_s=0.001, breaker_cooldown_s=0.02)
    with pmt.Session([sup], pool=pmt.SensorPool(), period_s=0.002) as sess:
        with sess.region("covered") as r:
            time.sleep(0.15)
        m = r.measurements[0]
        c = sup.health()["counters"]
        print(f"failover: {m.joules:.3f} J degraded={m.degraded} "
              f"(failovers={c['failovers']} failbacks={c['failbacks']} "
              f"state={sup.state})")

    # Without a fallback the outage becomes a labeled coverage gap.
    flaky = pmt.FaultInjectingSensor(pmt.create("dummy", watts=60.0),
                                     plan=[blackout])
    solo = pmt.SensorSupervisor([flaky], retries=0, breaker_cooldown_s=0.01)
    with pmt.Session([solo], pool=pmt.SensorPool(), period_s=0.002) as sess:
        ring = dict(sess.samplers())[solo.name]
        with sess.region("blackout") as r:
            time.sleep(0.15)
        m = r.measurements[0]
        print(f"blackout: {m.joules:.3f} J degraded={m.degraded} "
              f"(read_errors={ring.health()['read_errors']}, "
              f"stats={sess.stats()['degraded']} degraded span(s))")


def dump_mode():
    """Dump mode: background thread writes a power timeline."""
    sensor = pmt.create("dummy", watts_fn=lambda t: 75.0 + 25.0 * (t % 0.1) / 0.1)
    with sensor.dumping("/tmp/pmt_timeline.pmt", period_s=0.02):
        time.sleep(0.4)
    header, records = pmt.read_dump("/tmp/pmt_timeline.pmt")
    print(f"dump: {len(records)} samples, "
          f"{pmt.total_joules(records):.2f} J, "
          f"avg {pmt.average_watts(records):.1f} W "
          f"-> /tmp/pmt_timeline.pmt")


if __name__ == "__main__":
    print("== session mode (the unified API)")
    session_mode()
    print("\n== measurement mode (paper Listing 1, classic shim)")
    listing1_measurement_mode()
    print("\n== decorators, stacked (paper Listing 2 / Fig. 2)")
    listing2_decorators()
    print("\n== serving (continuous batching, per-request J/token)")
    serving_mode()
    print("\n== paged KV (page pools, radix prefix reuse)")
    paged_mode()
    print("\n== quantized KV (int8/fp8 rows, in-kernel dequant)")
    quantized_mode()
    print("\n== live telemetry & power capping (the control plane)")
    telemetry_mode()
    print("\n== fault tolerance (supervisor, degraded spans, fail-safe)")
    fault_tolerance_mode()
    print("\n== dump mode")
    dump_mode()
