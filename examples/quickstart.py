"""PMT quickstart — the paper's Listings 1 and 2, in this framework.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import time

import repro.core as pmt


def listing1_measurement_mode():
    """C++ Listing 1: create -> read -> work -> read -> derive."""
    sensor = pmt.create("cpuutil")          # measured host-CPU backend
    start = sensor.read()
    time.sleep(1.0)                          # the paper sleeps 5 s; 1 s here
    end = sensor.read()
    print(f"{sensor.joules(start, end):9.4f} [J]")
    print(f"{sensor.watts(start, end):9.4f} [W]")
    print(f"{sensor.seconds(start, end):9.4f} [S]")


def listing2_decorators():
    """Python Listing 2: stacked decorators, one line per backend."""

    @pmt.measure("tpu")        # modeled accelerator sensor
    @pmt.measure("cpuutil")    # measured host sensor
    def my_application():
        time.sleep(0.5)
        return 42

    measures = my_application()
    for m in measures:
        print(m)
    print("wrapped result:", measures.result)


def dump_mode():
    """Dump mode: background thread writes a power timeline."""
    sensor = pmt.create("dummy", watts_fn=lambda t: 75.0 + 25.0 * (t % 0.1) / 0.1)
    with sensor.dumping("/tmp/pmt_timeline.pmt", period_s=0.02):
        time.sleep(0.4)
    header, records = pmt.read_dump("/tmp/pmt_timeline.pmt")
    print(f"dump: {len(records)} samples, "
          f"{pmt.total_joules(records):.2f} J, "
          f"avg {pmt.average_watts(records):.1f} W "
          f"-> /tmp/pmt_timeline.pmt")


if __name__ == "__main__":
    print("== measurement mode (paper Listing 1)")
    listing1_measurement_mode()
    print("\n== decorators, stacked (paper Listing 2 / Fig. 2)")
    listing2_decorators()
    print("\n== dump mode")
    dump_mode()
