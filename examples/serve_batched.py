"""Batched serving example with J/token reporting.

Run: PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve as serve_launcher

if __name__ == "__main__":
    serve_launcher.main(["--arch", "qwen3-0.6b", "--reduced",
                         "--requests", "8", "--batch", "4",
                         "--max-new", "12"])
