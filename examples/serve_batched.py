"""Continuous-batching serving example with aggregate and per-request
J/token reporting (pass --mode wave for the synchronized baseline).

Run: PYTHONPATH=src python examples/serve_batched.py [launcher flags]
"""
import sys

from repro.launch import serve as serve_launcher

if __name__ == "__main__":
    # example defaults first; CLI flags appended so they win (argparse
    # keeps the last occurrence)
    serve_launcher.main(["--arch", "qwen3-0.6b", "--reduced",
                         "--requests", "8", "--batch", "4",
                         "--max-new", "12"] + sys.argv[1:])
